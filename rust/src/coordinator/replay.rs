//! The Quantized Latent Replay memory (paper §III-C) — the heart of QLR-CL.
//!
//! A fixed-capacity buffer of `N_LR` latent vectors. Storage modes:
//!  - **Packed UINT-Q** (Q ∈ 6..8): codes bit-packed into one contiguous
//!    arena with a single per-buffer affine scale (`S_a,l` from PTQ
//!    calibration) — the paper's 4x/4.57x memory compression;
//!  - **F32**: the paper's FP32 baseline arm (Table II).
//!
//! Replacement follows AR1*'s external-memory policy: after learning event
//! number `e`, `h = max(1, N_LR / e)` random slots are overwritten by
//! random latents of the event — early events populate the memory quickly,
//! later ones displace ever less (reservoir-flavored), keeping the buffer
//! approximately balanced over everything seen.

use anyhow::{ensure, Result};

use crate::quant::{
    pack_bits_into, packed_len, repack_narrow_in_place, repack_widen_in_place,
    unpack_dequant_range, ActQuantizer,
};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
enum Storage {
    /// bit-packed codes, `slot * latent_elems` code offset per slot; `lut`
    /// is the buffer's dequantization table (`lut[q] = q * S_a`, exact for
    /// all Q <= 8), built once and fed to the fused unpack+dequant reader
    Packed { bits: u8, quant: ActQuantizer, lut: Box<[f32; 256]>, arena: Vec<u8> },
    F32 { arena: Vec<f32> },
}

#[derive(Clone, Debug)]
pub struct ReplayBuffer {
    capacity: usize,
    latent_elems: usize,
    labels: Vec<i32>,
    /// indices of filled slots, in fill order. `filled_slots.len()` is the
    /// occupancy; sampling draws from THIS list, never from raw slot
    /// numbers — writes via `event_update` on a partially-filled buffer
    /// are not prefix-contiguous, so `slot < len()` does NOT imply
    /// `labels[slot] != -1`.
    filled_slots: Vec<u32>,
    storage: Storage,
    /// reusable quantize scratch for the insert path (codes are packed
    /// straight into the arena slot — no packed scratch needed)
    scratch_codes: Vec<u8>,
}

impl ReplayBuffer {
    /// Quantized buffer: `bits` ∈ 1..=8, `a_max` = latent dynamic range.
    ///
    /// Slots must be byte-aligned: `(latent_elems * bits) % 8 == 0`. This
    /// is a hard assert (not a debug one): a misaligned latent size would
    /// make `write_slot` bit-pack across slot boundaries and silently
    /// corrupt neighboring slots in release builds. Every real split of
    /// both networks has a multiple-of-8 latent size, so Q ∈ 6..8 always
    /// aligns; arbitrary (elems, Q) combinations are rejected here.
    pub fn new_packed(capacity: usize, latent_elems: usize, bits: u8, a_max: f32) -> Self {
        assert!(
            (latent_elems * bits as usize) % 8 == 0,
            "replay slots must be byte-aligned: latent_elems={latent_elems} x Q={bits} \
             = {} bits is not a whole number of bytes",
            latent_elems * bits as usize
        );
        let quant = ActQuantizer::new(bits, a_max);
        let lut = Box::new(quant.lut());
        let arena = vec![0u8; packed_len(capacity * latent_elems, bits)];
        ReplayBuffer {
            capacity,
            latent_elems,
            labels: vec![-1; capacity],
            filled_slots: Vec::with_capacity(capacity),
            storage: Storage::Packed { bits, quant, lut, arena },
            scratch_codes: vec![0; latent_elems],
        }
    }

    /// FP32 baseline buffer (no compression).
    pub fn new_f32(capacity: usize, latent_elems: usize) -> Self {
        ReplayBuffer {
            capacity,
            latent_elems,
            labels: vec![-1; capacity],
            filled_slots: Vec::with_capacity(capacity),
            storage: Storage::F32 { arena: vec![0.0; capacity * latent_elems] },
            scratch_codes: Vec::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn latent_elems(&self) -> usize {
        self.latent_elems
    }

    pub fn len(&self) -> usize {
        self.filled_slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.filled_slots.is_empty()
    }

    /// Memory footprint of the stored latents (the Fig 6 x-axis, at mini
    /// scale): packed arena bytes or 4 B/elem for FP32.
    pub fn storage_bytes(&self) -> usize {
        match &self.storage {
            Storage::Packed { arena, .. } => arena.len(),
            Storage::F32 { arena } => arena.len() * 4,
        }
    }

    /// Latent-arena bytes of a buffer sized `(capacity, latent_elems)` at
    /// `bits` (32 = FP32), **without building one** — the single source
    /// of truth the memory model ([`crate::models::memory`]) and the
    /// fleet governor's admission math both use, and exactly what
    /// [`ReplayBuffer::storage_bytes`] reports on the live buffer.
    pub fn arena_bytes_for(capacity: usize, latent_elems: usize, bits: u8) -> usize {
        if bits == 32 {
            capacity * latent_elems * 4
        } else {
            packed_len(capacity * latent_elems, bits)
        }
    }

    /// Full live footprint of a buffer sized `(capacity, latent_elems)`
    /// at `bits`: the latent arena plus per-slot bookkeeping (labels,
    /// filled-slot list) and the insert-path quantize scratch. Matches
    /// [`ReplayBuffer::bytes_used`] on a freshly built buffer.
    pub fn bytes_for(capacity: usize, latent_elems: usize, bits: u8) -> usize {
        let scratch = if bits == 32 { 0 } else { latent_elems };
        Self::arena_bytes_for(capacity, latent_elems, bits) + capacity * 8 + scratch
    }

    /// Live footprint of this buffer: arena + labels + filled-slot list +
    /// quantize scratch. This is what the fleet's [`MemoryGovernor`]
    /// charges against its global budget.
    ///
    /// [`MemoryGovernor`]: crate::fleet::MemoryGovernor
    pub fn bytes_used(&self) -> usize {
        let scratch = self.scratch_codes.len();
        // labels: 4 B/slot; filled-slot list: u32/slot, reserved up front
        self.storage_bytes() + self.capacity * 8 + scratch
    }

    /// Storage bit width: 6..8 for packed buffers, 32 for FP32.
    pub fn bits(&self) -> u8 {
        match &self.storage {
            Storage::Packed { bits, .. } => *bits,
            Storage::F32 { .. } => 32,
        }
    }

    /// Dynamic range the packed codec spans (`None` for FP32 buffers).
    pub fn a_max(&self) -> Option<f32> {
        match &self.storage {
            Storage::Packed { quant, .. } => Some(quant.a_max),
            Storage::F32 { .. } => None,
        }
    }

    /// Demote a packed buffer to a narrower code width **in place** (the
    /// governor's 8→7-bit pressure valve): every stored code — filled or
    /// not — is re-projected onto the `to_bits` grid over the same
    /// `a_max` via the integer round-to-nearest remap in
    /// [`repack_narrow_in_place`] (no dequantize/requantize round-trip),
    /// the arena shrinks to the narrower packed length, and the codec +
    /// LUT are rebuilt. Returns the bytes freed.
    ///
    /// Panics on FP32 buffers, widening requests, and `(latent_elems,
    /// to_bits)` combinations whose slots would not stay byte-aligned
    /// (same rule as [`ReplayBuffer::new_packed`]).
    pub fn demote_bits(&mut self, to_bits: u8) -> usize {
        assert!(
            (self.latent_elems * to_bits as usize) % 8 == 0,
            "demoted replay slots must stay byte-aligned: latent_elems={} x Q={to_bits}",
            self.latent_elems
        );
        match &mut self.storage {
            Storage::Packed { bits, quant, lut, arena } => {
                assert!(
                    to_bits < *bits,
                    "demote_bits: {to_bits} is not narrower than the current Q={}",
                    *bits
                );
                let before = arena.len();
                repack_narrow_in_place(arena, *bits, to_bits, self.capacity * self.latent_elems);
                // actually return the freed tail to the allocator — the
                // governor's whole point is the HOST footprint, and
                // truncate alone keeps the old capacity reserved
                arena.shrink_to_fit();
                *quant = ActQuantizer::new(to_bits, quant.a_max);
                *lut = Box::new(quant.lut());
                *bits = to_bits;
                before - arena.len()
            }
            Storage::F32 { .. } => panic!("demote_bits: FP32 buffers have no code width"),
        }
    }

    /// Promote a packed buffer to a wider code width **in place** (the
    /// governor's 7→8-bit recovery valve when memory pressure clears):
    /// every stored code is re-projected onto the `to_bits` grid over the
    /// same `a_max` via the integer round-to-nearest remap in
    /// [`repack_widen_in_place`], the arena grows to the wider packed
    /// length, and the codec + LUT are rebuilt. Returns the bytes
    /// *added*. Widening is exactly reversible (`narrow(widen(q)) == q`),
    /// so a promote→demote cycle restores the pre-promotion buffer
    /// bit-for-bit; precision lost by the earlier demotion is not
    /// recovered, but everything written after the promotion enjoys the
    /// full `to_bits` grid again.
    ///
    /// Panics on FP32 buffers, narrowing requests, and `(latent_elems,
    /// to_bits)` combinations whose slots would not stay byte-aligned
    /// (same rule as [`ReplayBuffer::new_packed`]).
    pub fn promote_bits(&mut self, to_bits: u8) -> usize {
        assert!(
            (self.latent_elems * to_bits as usize) % 8 == 0,
            "promoted replay slots must stay byte-aligned: latent_elems={} x Q={to_bits}",
            self.latent_elems
        );
        match &mut self.storage {
            Storage::Packed { bits, quant, lut, arena } => {
                assert!(
                    to_bits > *bits,
                    "promote_bits: {to_bits} is not wider than the current Q={}",
                    *bits
                );
                let before = arena.len();
                repack_widen_in_place(arena, *bits, to_bits, self.capacity * self.latent_elems);
                *quant = ActQuantizer::new(to_bits, quant.a_max);
                *lut = Box::new(quant.lut());
                *bits = to_bits;
                arena.len() - before
            }
            Storage::F32 { .. } => panic!("promote_bits: FP32 buffers have no code width"),
        }
    }

    // ---- serialization raw parts (the fleet snapshot codec) -------------

    /// All slot labels (`-1` marks unfilled) — snapshot export.
    pub fn labels_raw(&self) -> &[i32] {
        &self.labels
    }

    /// Filled-slot list in fill order — snapshot export.
    pub fn filled_slots_raw(&self) -> &[u32] {
        &self.filled_slots
    }

    /// Packed-mode internals `(arena, bits, a_max)`; `None` for FP32
    /// buffers — snapshot export.
    pub fn packed_parts(&self) -> Option<(&[u8], u8, f32)> {
        match &self.storage {
            Storage::Packed { bits, quant, arena, .. } => Some((arena, *bits, quant.a_max)),
            Storage::F32 { .. } => None,
        }
    }

    /// FP32-mode arena; `None` for packed buffers — snapshot export.
    pub fn f32_arena(&self) -> Option<&[f32]> {
        match &self.storage {
            Storage::F32 { arena } => Some(arena),
            Storage::Packed { .. } => None,
        }
    }

    /// Rebuild a **packed** buffer from serialized parts, validating every
    /// structural invariant the in-memory constructors enforce by
    /// assertion — a corrupted or hand-edited snapshot must surface as a
    /// clean `Err`, never as a panic or silent slot corruption.
    pub fn from_packed_parts(
        capacity: usize,
        latent_elems: usize,
        bits: u8,
        a_max: f32,
        arena: Vec<u8>,
        labels: Vec<i32>,
        filled_slots: Vec<u32>,
    ) -> Result<ReplayBuffer> {
        ensure!((1..=8).contains(&bits), "replay snapshot: bad bit width {bits}");
        ensure!(a_max > 0.0 && a_max.is_finite(), "replay snapshot: bad a_max {a_max}");
        ensure!(
            (latent_elems * bits as usize) % 8 == 0,
            "replay snapshot: misaligned slots ({latent_elems} elems x Q={bits})"
        );
        ensure!(
            arena.len() == packed_len(capacity * latent_elems, bits),
            "replay snapshot: arena length {} != expected {}",
            arena.len(),
            packed_len(capacity * latent_elems, bits)
        );
        let quant = ActQuantizer::new(bits, a_max);
        let lut = Box::new(quant.lut());
        let b = ReplayBuffer {
            capacity,
            latent_elems,
            labels,
            filled_slots,
            storage: Storage::Packed { bits, quant, lut, arena },
            scratch_codes: vec![0; latent_elems],
        };
        b.validate_slot_book()?;
        Ok(b)
    }

    /// Rebuild an **FP32** buffer from serialized parts (see
    /// [`ReplayBuffer::from_packed_parts`]).
    pub fn from_f32_parts(
        capacity: usize,
        latent_elems: usize,
        arena: Vec<f32>,
        labels: Vec<i32>,
        filled_slots: Vec<u32>,
    ) -> Result<ReplayBuffer> {
        ensure!(
            arena.len() == capacity * latent_elems,
            "replay snapshot: arena length {} != expected {}",
            arena.len(),
            capacity * latent_elems
        );
        let b = ReplayBuffer {
            capacity,
            latent_elems,
            labels,
            filled_slots,
            storage: Storage::F32 { arena },
            scratch_codes: Vec::new(),
        };
        b.validate_slot_book()?;
        Ok(b)
    }

    /// Shared deserialization validation: labels/filled-slot consistency.
    fn validate_slot_book(&self) -> Result<()> {
        ensure!(
            self.labels.len() == self.capacity,
            "replay snapshot: {} labels for capacity {}",
            self.labels.len(),
            self.capacity
        );
        let mut seen = vec![false; self.capacity];
        for &slot in &self.filled_slots {
            let s = slot as usize;
            ensure!(s < self.capacity, "replay snapshot: filled slot {s} out of range");
            ensure!(!seen[s], "replay snapshot: duplicate filled slot {s}");
            ensure!(
                self.labels[s] >= 0,
                "replay snapshot: filled slot {s} has empty-marker label"
            );
            seen[s] = true;
        }
        let labeled = self.labels.iter().filter(|&&l| l >= 0).count();
        ensure!(
            labeled == self.filled_slots.len(),
            "replay snapshot: {} labeled slots but {} filled entries",
            labeled,
            self.filled_slots.len()
        );
        Ok(())
    }

    /// Shrink the slot count to `new_capacity` **in place** (the
    /// governor's second pressure valve, after bit demotion). Filled
    /// slots are compacted to the front in ascending slot order — the
    /// lowest-numbered `new_capacity` filled slots survive, the rest are
    /// dropped (sampling is uniform over the filled set, so fill order
    /// carries no semantic weight). Returns the bytes freed.
    pub fn shrink_capacity(&mut self, new_capacity: usize) -> usize {
        assert!(new_capacity >= 1, "shrink_capacity: capacity must stay >= 1");
        if new_capacity >= self.capacity {
            return 0;
        }
        let before = self.bytes_used();
        // keep the lowest-numbered filled slots: ascending order makes
        // every move front-ward (dst index i <= kept[i]), so the forward
        // compaction below never overwrites a slot it has yet to read
        let mut kept: Vec<u32> = self.filled_slots.clone();
        kept.sort_unstable();
        kept.truncate(new_capacity);
        match &mut self.storage {
            Storage::Packed { bits, arena, .. } => {
                let bps = packed_len(self.latent_elems, *bits);
                for (i, &slot) in kept.iter().enumerate() {
                    let (dst, src) = (i * bps, slot as usize * bps);
                    if dst != src {
                        arena.copy_within(src..src + bps, dst);
                    }
                }
                arena.truncate(packed_len(new_capacity * self.latent_elems, *bits));
                arena.shrink_to_fit(); // release, don't just truncate
            }
            Storage::F32 { arena } => {
                let le = self.latent_elems;
                for (i, &slot) in kept.iter().enumerate() {
                    let (dst, src) = (i * le, slot as usize * le);
                    if dst != src {
                        arena.copy_within(src..src + le, dst);
                    }
                }
                arena.truncate(new_capacity * le);
                arena.shrink_to_fit(); // release, don't just truncate
            }
        }
        let old_labels = std::mem::replace(&mut self.labels, vec![-1; new_capacity]);
        for (i, &slot) in kept.iter().enumerate() {
            self.labels[i] = old_labels[slot as usize];
        }
        self.filled_slots = (0..kept.len() as u32).collect();
        self.capacity = new_capacity;
        before - self.bytes_used()
    }

    pub fn label(&self, slot: usize) -> i32 {
        self.labels[slot]
    }

    /// Write `latent` into `slot` (quantizing/packing as configured).
    pub fn write_slot(&mut self, slot: usize, latent: &[f32], label: i32) {
        assert!(slot < self.capacity, "slot {slot} out of range");
        assert!(label >= 0, "label must be non-negative (-1 marks empty slots)");
        assert_eq!(latent.len(), self.latent_elems, "latent size mismatch");
        match &mut self.storage {
            Storage::Packed { bits, quant, arena, .. } => {
                quant.quantize(latent, &mut self.scratch_codes);
                // pack the slot's codes straight into the arena — slots
                // are whole-byte aligned ((elems*bits)%8 == 0, enforced by
                // `new_packed`'s hard assert), so this write can never
                // bit-pack across a neighboring slot
                let bytes_per_slot = packed_len(self.latent_elems, *bits);
                let off = slot * bytes_per_slot;
                pack_bits_into(&self.scratch_codes, *bits, &mut arena[off..off + bytes_per_slot]);
            }
            Storage::F32 { arena } => {
                let off = slot * self.latent_elems;
                arena[off..off + self.latent_elems].copy_from_slice(latent);
            }
        }
        if self.labels[slot] == -1 {
            self.filled_slots.push(slot as u32);
        }
        self.labels[slot] = label;
    }

    /// Dequantize slot `slot` into `out` (the FP32 view the adaptive stage
    /// trains on: `S_a * code`, or the raw value in F32 mode). Packed
    /// slots go through the fused unpack+dequant reader: one pass over the
    /// arena straight into the caller's slice — no code scratch, no
    /// allocation, and a byte-indexed fast path at Q=8.
    pub fn read_slot_into(&self, slot: usize, out: &mut [f32]) {
        assert!(slot < self.capacity && self.labels[slot] != -1, "reading unfilled slot {slot}");
        assert_eq!(out.len(), self.latent_elems);
        match &self.storage {
            Storage::Packed { bits, lut, arena, .. } => {
                unpack_dequant_range(arena, *bits, slot * self.latent_elems, lut, out);
            }
            Storage::F32 { arena } => {
                let off = slot * self.latent_elems;
                out.copy_from_slice(&arena[off..off + self.latent_elems]);
            }
        }
    }

    /// Initial fill from the pre-deployment latents (paper: LRs sampled
    /// from the 3000 initial images). Takes `min(n, capacity)` distinct
    /// random rows — when the initial set is smaller than `N_LR` the
    /// buffer starts partially filled and later `event_update`s grow it
    /// (sampling stays sound either way: draws come from the filled-slot
    /// list, never from raw slot numbers).
    pub fn init_fill(&mut self, latents: &[f32], labels: &[i32], rng: &mut Rng) {
        let n = labels.len();
        assert_eq!(latents.len(), n * self.latent_elems);
        let take = n.min(self.capacity);
        let picks = rng.sample_indices(n, take);
        for (slot, &src) in picks.iter().enumerate() {
            self.write_slot(
                slot,
                &latents[src * self.latent_elems..(src + 1) * self.latent_elems],
                labels[src],
            );
        }
    }

    /// AR1*-style post-event update: overwrite `h = max(1, cap/event_idx)`
    /// random slots with random latents from the event (`event_idx` is
    /// 1-based). Returns `h`.
    pub fn event_update(
        &mut self,
        latents: &[f32],
        labels: &[i32],
        event_idx: usize,
        rng: &mut Rng,
    ) -> usize {
        assert!(event_idx >= 1);
        let n = labels.len();
        assert_eq!(latents.len(), n * self.latent_elems);
        let h = (self.capacity / event_idx).max(1).min(n).min(self.capacity);
        let dst = rng.sample_indices(self.capacity, h);
        let src = rng.sample_indices(n, h);
        for (&d, &s) in dst.iter().zip(&src) {
            self.write_slot(
                d,
                &latents[s * self.latent_elems..(s + 1) * self.latent_elems],
                labels[s],
            );
        }
        h
    }

    /// Sample `k` slots (with replacement, as the paper's minibatch mixer)
    /// dequantized into `out` (`k * latent_elems`), labels into
    /// `out_labels`. Read-only and allocation-free: every sampled slot is
    /// fused-dequantized straight into the caller's batch slice.
    ///
    /// Draws index into the filled-slot list, so holes left by
    /// `event_update` on a partially-filled buffer are never sampled
    /// (sampling a raw `slot < len()` would hit `label == -1` slots).
    pub fn sample_into(
        &self,
        k: usize,
        rng: &mut Rng,
        out: &mut [f32],
        out_labels: &mut [i32],
    ) {
        assert!(!self.filled_slots.is_empty(), "sampling from empty replay buffer");
        assert_eq!(out.len(), k * self.latent_elems);
        assert_eq!(out_labels.len(), k);
        for i in 0..k {
            let slot = self.filled_slots[rng.below(self.filled_slots.len())] as usize;
            out_labels[i] = self.labels[slot];
            let dst = &mut out[i * self.latent_elems..(i + 1) * self.latent_elems];
            self.read_slot_into(slot, dst);
        }
    }

    /// Per-class slot counts (buffer-balance diagnostics + tests).
    pub fn class_histogram(&self, n_classes: usize) -> Vec<usize> {
        let mut h = vec![0usize; n_classes];
        for &slot in &self.filled_slots {
            let l = self.labels[slot as usize];
            if l >= 0 && (l as usize) < n_classes {
                h[l as usize] += 1;
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ActQuantizer;
    use crate::util::prop;

    fn ramp(n: usize, base: f32) -> Vec<f32> {
        (0..n).map(|i| base + i as f32 * 0.01).collect()
    }

    #[test]
    fn fused_read_is_bit_exact_vs_quantizer_dequantize() {
        // the fused unpack+dequant path must produce the very same f32s
        // as quantize -> unpack -> ActQuantizer::dequantize, for every Q
        prop::check("replay fused read", 64, |rng| {
            let bits = prop::int_in(rng, 1, 8) as u8;
            let elems = 8 * prop::int_in(rng, 1, 16); // byte-aligned slots
            let a_max = 0.5 + rng.f32() * 4.0;
            let mut b = ReplayBuffer::new_packed(2, elems, bits, a_max);
            let lat = prop::vec_f32(rng, elems, 0.0, a_max);
            b.write_slot(0, &lat, 1);
            let mut fused = vec![0f32; elems];
            b.read_slot_into(0, &mut fused);
            let q = ActQuantizer::new(bits, a_max);
            let mut codes = Vec::new();
            q.quantize(&lat, &mut codes);
            let mut reference = vec![0f32; elems];
            q.dequantize(&codes, &mut reference);
            for (f, r) in fused.iter().zip(&reference) {
                assert_eq!(f.to_bits(), r.to_bits(), "bits={bits} a_max={a_max}");
            }
        });
    }

    #[test]
    fn write_read_roundtrip_f32_exact() {
        let mut b = ReplayBuffer::new_f32(4, 16);
        let lat = ramp(16, 0.5);
        b.write_slot(2, &lat, 7);
        let mut out = vec![0f32; 16];
        // slot 2 written but filled counts only non-(-1) labels; write slots 0,1 too
        b.write_slot(0, &lat, 1);
        b.write_slot(1, &lat, 2);
        b.read_slot_into(2, &mut out);
        assert_eq!(out, lat);
        assert_eq!(b.label(2), 7);
    }

    #[test]
    fn packed_roundtrip_error_bounded() {
        prop::check("replay packed roundtrip", 64, |rng| {
            let bits = prop::int_in(rng, 6, 8) as u8;
            let elems = 8 * prop::int_in(rng, 1, 32); // byte-aligned slots
            let a_max = 1.0 + rng.f32() * 4.0;
            let mut b = ReplayBuffer::new_packed(3, elems, bits, a_max);
            let lat = prop::vec_f32(rng, elems, 0.0, a_max);
            b.write_slot(0, &lat, 3);
            let mut out = vec![0f32; elems];
            b.read_slot_into(0, &mut out);
            let step = a_max / ((1u32 << bits) - 1) as f32;
            for (&x, &y) in lat.iter().zip(&out) {
                assert!((x - y).abs() <= step * (1.0 + 1e-5));
            }
        });
    }

    #[test]
    fn storage_bytes_match_compression() {
        let b8 = ReplayBuffer::new_packed(100, 1024, 8, 1.0);
        let b7 = ReplayBuffer::new_packed(100, 1024, 7, 1.0);
        let b6 = ReplayBuffer::new_packed(100, 1024, 6, 1.0);
        let f = ReplayBuffer::new_f32(100, 1024);
        assert_eq!(b8.storage_bytes(), 100 * 1024);
        assert_eq!(b7.storage_bytes(), 100 * 1024 * 7 / 8);
        assert_eq!(b6.storage_bytes(), 100 * 1024 * 6 / 8);
        assert_eq!(f.storage_bytes(), 100 * 1024 * 4);
    }

    #[test]
    fn init_fill_fills_and_respects_labels() {
        let mut rng = Rng::new(1);
        let elems = 8;
        let n = 50;
        let latents: Vec<f32> = (0..n * elems).map(|i| (i % 97) as f32 * 0.01).collect();
        let labels: Vec<i32> = (0..n as i32).map(|i| i % 4).collect();
        let mut b = ReplayBuffer::new_packed(20, elems, 8, 1.0);
        b.init_fill(&latents, &labels, &mut rng);
        assert_eq!(b.len(), 20);
        let hist = b.class_histogram(4);
        assert_eq!(hist.iter().sum::<usize>(), 20);
        assert!(hist.iter().all(|&c| c > 0), "all classes represented: {hist:?}");
    }

    #[test]
    fn event_update_h_decays() {
        let mut rng = Rng::new(2);
        let elems = 8;
        let mut b = ReplayBuffer::new_f32(64, elems);
        let latents = vec![0.25f32; 100 * elems];
        let labels = vec![5i32; 100];
        b.init_fill(&latents[..64 * elems], &labels[..64], &mut rng);
        let h1 = b.event_update(&latents, &labels, 1, &mut rng);
        let h4 = b.event_update(&latents, &labels, 4, &mut rng);
        let h100 = b.event_update(&latents, &labels, 100, &mut rng);
        assert_eq!(h1, 64);
        assert_eq!(h4, 16);
        assert_eq!(h100, 1);
    }

    #[test]
    fn event_update_inserts_new_class() {
        let mut rng = Rng::new(3);
        let elems = 8;
        let mut b = ReplayBuffer::new_packed(32, elems, 8, 1.0);
        let lat0 = vec![0.1f32; 40 * elems];
        let lab0 = vec![0i32; 40];
        b.init_fill(&lat0, &lab0, &mut rng);
        let lat1 = vec![0.9f32; 40 * elems];
        let lab1 = vec![1i32; 40];
        b.event_update(&lat1, &lab1, 2, &mut rng); // h = 16
        let hist = b.class_histogram(2);
        assert_eq!(hist[0] + hist[1], 32);
        assert_eq!(hist[1], 16);
    }

    #[test]
    fn sample_into_draws_valid() {
        let mut rng = Rng::new(4);
        let elems = 16;
        let mut b = ReplayBuffer::new_packed(10, elems, 7, 2.0);
        let latents: Vec<f32> = (0..10 * elems).map(|i| (i as f32 * 0.007) % 2.0).collect();
        let labels: Vec<i32> = (0..10).collect();
        b.init_fill(&latents, &labels, &mut rng);
        let k = 30;
        let mut out = vec![0f32; k * elems];
        let mut labs = vec![0i32; k];
        b.sample_into(k, &mut rng, &mut out, &mut labs);
        assert!(labs.iter().all(|&l| (0..10).contains(&l)));
        let step = 2.0 / 127.0f32;
        assert!(out.iter().all(|&v| v >= 0.0 && v <= 2.0 + step));
    }

    #[test]
    fn event_update_before_init_fill_leaves_no_sampling_holes() {
        // regression: event_update on a never-init_fill'ed buffer writes
        // non-contiguous slots; sampling used to draw raw `slot < filled`
        // indices and could land on label == -1 holes (panic in the packed
        // read path, silent skew otherwise)
        let mut rng = Rng::new(11);
        let elems = 8;
        let mut b = ReplayBuffer::new_packed(64, elems, 8, 1.0);
        let latents = vec![0.5f32; 20 * elems];
        let labels = vec![3i32; 20];
        // event 4 -> h = 16 random slots out of 64 (holes guaranteed)
        let h = b.event_update(&latents, &labels, 4, &mut rng);
        assert_eq!(h, 16);
        assert_eq!(b.len(), 16);
        let k = 200;
        let mut out = vec![0f32; k * elems];
        let mut labs = vec![-7i32; k];
        b.sample_into(k, &mut rng, &mut out, &mut labs);
        assert!(
            labs.iter().all(|&l| l == 3),
            "sampled a hole: labels {:?}",
            &labs[..8]
        );
    }

    #[test]
    fn partial_init_fill_supported() {
        // fewer initial latents than capacity: the buffer starts partially
        // filled and sampling draws only from the filled prefix
        let mut rng = Rng::new(12);
        let elems = 8;
        let mut b = ReplayBuffer::new_packed(32, elems, 8, 1.0);
        let latents: Vec<f32> = (0..10 * elems).map(|i| (i % 13) as f32 * 0.05).collect();
        let labels: Vec<i32> = (0..10).collect();
        b.init_fill(&latents, &labels, &mut rng);
        assert_eq!(b.len(), 10);
        let mut out = vec![0f32; 50 * elems];
        let mut labs = vec![0i32; 50];
        b.sample_into(50, &mut rng, &mut out, &mut labs);
        assert!(labs.iter().all(|&l| (0..10).contains(&l)));
        // growth continues through event updates
        b.event_update(&latents, &labels, 1, &mut rng);
        assert!(b.len() >= 10);
    }

    #[test]
    #[should_panic(expected = "byte-aligned")]
    fn misaligned_q6_slots_rejected() {
        // 10 elems x 6 bits = 60 bits: slots would straddle byte limits
        // and bit-pack into their neighbors — must be rejected up front
        let _ = ReplayBuffer::new_packed(4, 10, 6, 1.0);
    }

    #[test]
    #[should_panic(expected = "byte-aligned")]
    fn misaligned_q7_slots_rejected() {
        // 4 elems x 7 bits = 28 bits
        let _ = ReplayBuffer::new_packed(4, 4, 7, 1.0);
    }

    #[test]
    fn aligned_sub_byte_slots_accepted() {
        // (elems * Q) % 8 == 0 without elems % 8 == 0: still byte-aligned
        let b6 = ReplayBuffer::new_packed(4, 4, 6, 1.0); // 24 bits
        assert_eq!(b6.storage_bytes(), 4 * 3);
        let b7 = ReplayBuffer::new_packed(4, 16, 7, 1.0); // 112 bits
        assert_eq!(b7.storage_bytes(), 4 * 14);
    }

    #[test]
    fn bytes_used_matches_bytes_for() {
        for bits in [6u8, 7, 8, 32] {
            let b = if bits == 32 {
                ReplayBuffer::new_f32(40, 64)
            } else {
                ReplayBuffer::new_packed(40, 64, bits, 1.0)
            };
            assert_eq!(b.bytes_used(), ReplayBuffer::bytes_for(40, 64, bits), "Q={bits}");
            assert_eq!(b.storage_bytes(), ReplayBuffer::arena_bytes_for(40, 64, bits));
            assert_eq!(b.bits(), bits);
        }
    }

    #[test]
    fn demote_8_to_7_preserves_values_within_half_new_step() {
        prop::check("replay demote", 48, |rng| {
            let elems = 8 * prop::int_in(rng, 1, 16);
            let a_max = 0.5 + rng.f32() * 4.0;
            let cap = prop::int_in(rng, 1, 12);
            let mut b = ReplayBuffer::new_packed(cap, elems, 8, a_max);
            let n_fill = prop::int_in(rng, 1, cap);
            let latents: Vec<f32> = prop::vec_f32(rng, n_fill * elems, 0.0, a_max);
            let labels: Vec<i32> = (0..n_fill as i32).collect();
            b.init_fill(&latents, &labels, rng);
            let mut before = vec![0f32; elems];
            let mut after = vec![0f32; elems];
            b.read_slot_into(0, &mut before);
            let arena8 = b.storage_bytes();
            let freed = b.demote_bits(7);
            assert_eq!(b.bits(), 7);
            assert_eq!(freed, arena8 - b.storage_bytes());
            assert_eq!(b.storage_bytes(), ReplayBuffer::arena_bytes_for(cap, elems, 7));
            assert_eq!(b.len(), n_fill, "occupancy must survive demotion");
            b.read_slot_into(0, &mut after);
            // round-to-nearest remap: at most half a 7-bit step of drift
            // from the stored 8-bit value (+ f32 eps slack)
            let step7 = a_max / 127.0;
            for (x, y) in before.iter().zip(&after) {
                assert!(
                    (x - y).abs() <= step7 * 0.5 * (1.0 + 1e-5),
                    "a_max={a_max}: {x} -> {y} drifted more than S7/2"
                );
            }
        });
    }

    #[test]
    fn promote_7_to_8_is_exact_on_stored_codes_and_reversible() {
        prop::check("replay promote", 48, |rng| {
            let elems = 8 * prop::int_in(rng, 1, 16);
            let a_max = 0.5 + rng.f32() * 4.0;
            let cap = prop::int_in(rng, 1, 12);
            let mut b = ReplayBuffer::new_packed(cap, elems, 8, a_max);
            let n_fill = prop::int_in(rng, 1, cap);
            let latents: Vec<f32> = prop::vec_f32(rng, n_fill * elems, 0.0, a_max);
            let labels: Vec<i32> = (0..n_fill as i32).collect();
            b.init_fill(&latents, &labels, rng);
            b.demote_bits(7);
            // capture the warm (7-bit) state, promote, demote again: the
            // round trip must be bit-exact — widening is reversible
            let mut warm = vec![0f32; elems];
            b.read_slot_into(0, &mut warm);
            let arena7 = b.storage_bytes();
            let grown = b.promote_bits(8);
            assert_eq!(b.bits(), 8);
            assert_eq!(grown, b.storage_bytes() - arena7);
            assert_eq!(b.storage_bytes(), ReplayBuffer::arena_bytes_for(cap, elems, 8));
            assert_eq!(b.len(), n_fill, "occupancy must survive promotion");
            // promoted values drift at most half an 8-bit step from warm
            let mut hot = vec![0f32; elems];
            b.read_slot_into(0, &mut hot);
            let step8 = a_max / 255.0;
            for (w, h) in warm.iter().zip(&hot) {
                assert!((w - h).abs() <= step8 * 0.5 * (1.0 + 1e-5));
            }
            b.demote_bits(7);
            let mut back = vec![0f32; elems];
            b.read_slot_into(0, &mut back);
            for (w, x) in warm.iter().zip(&back) {
                assert_eq!(w.to_bits(), x.to_bits(), "promote/demote cycle drifted");
            }
        });
    }

    #[test]
    fn raw_parts_round_trip_is_bit_exact() {
        // the snapshot codec's export/import path: rebuilt buffers must
        // read back every slot identically, packed and FP32 alike
        let mut rng = Rng::new(31);
        let elems = 16;
        for bits in [7u8, 8, 32] {
            let mut b = if bits == 32 {
                ReplayBuffer::new_f32(12, elems)
            } else {
                ReplayBuffer::new_packed(12, elems, bits, 1.5)
            };
            let latents: Vec<f32> = (0..8 * elems).map(|i| (i % 29) as f32 * 0.05).collect();
            let labels: Vec<i32> = (0..8).collect();
            b.init_fill(&latents, &labels, &mut rng);
            let rebuilt = if bits == 32 {
                ReplayBuffer::from_f32_parts(
                    b.capacity(),
                    elems,
                    b.f32_arena().unwrap().to_vec(),
                    b.labels_raw().to_vec(),
                    b.filled_slots_raw().to_vec(),
                )
                .unwrap()
            } else {
                let (arena, pb, a_max) = b.packed_parts().unwrap();
                ReplayBuffer::from_packed_parts(
                    b.capacity(),
                    elems,
                    pb,
                    a_max,
                    arena.to_vec(),
                    b.labels_raw().to_vec(),
                    b.filled_slots_raw().to_vec(),
                )
                .unwrap()
            };
            assert_eq!(rebuilt.len(), b.len());
            let (mut x, mut y) = (vec![0f32; elems], vec![0f32; elems]);
            for slot in 0..8 {
                b.read_slot_into(slot, &mut x);
                rebuilt.read_slot_into(slot, &mut y);
                assert_eq!(rebuilt.label(slot), b.label(slot));
                for (a, c) in x.iter().zip(&y) {
                    assert_eq!(a.to_bits(), c.to_bits(), "Q={bits} slot={slot}");
                }
            }
        }
    }

    #[test]
    fn raw_parts_reject_inconsistent_books() {
        // wrong arena length
        assert!(ReplayBuffer::from_packed_parts(4, 8, 8, 1.0, vec![0; 31], vec![-1; 4], vec![])
            .is_err());
        // filled slot out of range
        assert!(ReplayBuffer::from_packed_parts(4, 8, 8, 1.0, vec![0; 32], vec![-1; 4], vec![9])
            .is_err());
        // filled slot marked empty
        assert!(ReplayBuffer::from_packed_parts(4, 8, 8, 1.0, vec![0; 32], vec![-1; 4], vec![1])
            .is_err());
        // duplicate filled slot
        assert!(ReplayBuffer::from_packed_parts(
            4,
            8,
            8,
            1.0,
            vec![0; 32],
            vec![2, -1, -1, -1],
            vec![0, 0]
        )
        .is_err());
        // labeled slot missing from the filled list
        assert!(ReplayBuffer::from_packed_parts(
            4,
            8,
            8,
            1.0,
            vec![0; 32],
            vec![2, 3, -1, -1],
            vec![0]
        )
        .is_err());
        // misaligned slots
        assert!(ReplayBuffer::from_packed_parts(4, 4, 7, 1.0, vec![0; 14], vec![-1; 4], vec![])
            .is_err());
        // wrong f32 arena length
        assert!(ReplayBuffer::from_f32_parts(4, 8, vec![0.0; 31], vec![-1; 4], vec![]).is_err());
    }

    #[test]
    #[should_panic(expected = "no code width")]
    fn promote_f32_rejected() {
        let mut b = ReplayBuffer::new_f32(4, 8);
        b.promote_bits(8);
    }

    #[test]
    #[should_panic(expected = "not wider")]
    fn promote_to_narrower_width_rejected() {
        let mut b = ReplayBuffer::new_packed(4, 8, 8, 1.0);
        b.promote_bits(8);
    }

    #[test]
    #[should_panic(expected = "no code width")]
    fn demote_f32_rejected() {
        let mut b = ReplayBuffer::new_f32(4, 8);
        b.demote_bits(7);
    }

    #[test]
    #[should_panic(expected = "byte-aligned")]
    fn demote_to_misaligned_width_rejected() {
        // 4 elems x 6 bits = 24 bits aligns, but 4 x 7 = 28 does not
        let mut b = ReplayBuffer::new_packed(4, 4, 8, 1.0);
        b.demote_bits(7);
    }

    #[test]
    fn shrink_keeps_lowest_filled_slots_and_frees_bytes() {
        let mut rng = Rng::new(21);
        let elems = 16;
        let mut b = ReplayBuffer::new_packed(32, elems, 8, 1.0);
        let latents: Vec<f32> = (0..32 * elems).map(|i| (i % 11) as f32 * 0.05).collect();
        let labels: Vec<i32> = (0..32).collect();
        b.init_fill(&latents, &labels, &mut rng);
        let mut kept_vals: Vec<(i32, Vec<f32>)> = Vec::new();
        for slot in 0..8 {
            let mut v = vec![0f32; elems];
            b.read_slot_into(slot, &mut v);
            kept_vals.push((b.label(slot), v));
        }
        let before = b.bytes_used();
        let freed = b.shrink_capacity(8);
        assert_eq!(b.capacity(), 8);
        assert_eq!(b.len(), 8);
        assert_eq!(freed, before - b.bytes_used());
        assert_eq!(b.bytes_used(), ReplayBuffer::bytes_for(8, elems, 8));
        // init_fill filled every slot, so the lowest 8 slots survive as-is
        for (slot, (label, vals)) in kept_vals.iter().enumerate() {
            let mut v = vec![0f32; elems];
            b.read_slot_into(slot, &mut v);
            assert_eq!(b.label(slot), *label);
            assert_eq!(&v, vals, "slot {slot} content changed across shrink");
        }
        // sampling still sound after the shrink
        let mut out = vec![0f32; 20 * elems];
        let mut labs = vec![-9i32; 20];
        b.sample_into(20, &mut rng, &mut out, &mut labs);
        assert!(labs.iter().all(|&l| (0..8).contains(&l)), "{labs:?}");
    }

    #[test]
    fn shrink_compacts_sparse_fill() {
        // holes from event_update: kept slots move front-ward, none lost
        let mut rng = Rng::new(22);
        let elems = 8;
        let mut b = ReplayBuffer::new_f32(64, elems);
        let latents = vec![0.75f32; 20 * elems];
        let labels = vec![4i32; 20];
        let h = b.event_update(&latents, &labels, 4, &mut rng); // 16 random slots
        assert_eq!(h, 16);
        b.shrink_capacity(10);
        assert_eq!(b.len(), 10);
        let mut out = vec![0f32; 30 * elems];
        let mut labs = vec![0i32; 30];
        b.sample_into(30, &mut rng, &mut out, &mut labs);
        assert!(labs.iter().all(|&l| l == 4));
        assert!(out.iter().all(|&v| v == 0.75));
    }

    #[test]
    fn demote_then_train_roundtrip_still_bounded() {
        // post-demotion reads stay on the 7-bit grid of the same a_max
        let mut rng = Rng::new(23);
        let elems = 24;
        let a_max = 2.0;
        let mut b = ReplayBuffer::new_packed(6, elems, 8, a_max);
        let latents: Vec<f32> = prop::vec_f32(&mut rng, 6 * elems, 0.0, a_max);
        let labels: Vec<i32> = (0..6).collect();
        b.init_fill(&latents, &labels, &mut rng);
        b.demote_bits(7);
        let step7 = a_max / 127.0;
        let step8 = a_max / 255.0;
        let mut out = vec![0f32; elems];
        for (slot, &lab) in labels.iter().enumerate() {
            b.read_slot_into(slot, &mut out);
            assert_eq!(b.label(slot), lab);
            // total error vs the original float: one 8-bit floor step
            // plus half a 7-bit rounding step
            for (o, x) in out.iter().zip(&latents[slot * elems..(slot + 1) * elems]) {
                assert!((o - x).abs() <= step8 + 0.5 * step7 + 1e-5);
            }
        }
    }

    #[test]
    #[should_panic(expected = "sampling from empty")]
    fn sampling_empty_panics() {
        let mut b = ReplayBuffer::new_f32(4, 8);
        let mut out = vec![0f32; 8];
        let mut labs = vec![0i32; 1];
        b.sample_into(1, &mut Rng::new(0), &mut out, &mut labs);
    }

    #[test]
    fn deterministic_under_seed() {
        let run = |seed| {
            let mut rng = Rng::new(seed);
            let elems = 8;
            let mut b = ReplayBuffer::new_packed(16, elems, 8, 1.0);
            let latents: Vec<f32> = (0..32 * elems).map(|i| (i % 13) as f32 * 0.05).collect();
            let labels: Vec<i32> = (0..32).map(|i| (i % 3) as i32).collect();
            b.init_fill(&latents, &labels, &mut rng);
            let mut out = vec![0f32; 4 * elems];
            let mut labs = vec![0i32; 4];
            b.sample_into(4, &mut rng, &mut out, &mut labs);
            (out, labs)
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9).1, run(10).1);
    }
}
