//! The Quantized Latent Replay memory (paper §III-C) — the heart of QLR-CL.
//!
//! A fixed-capacity buffer of `N_LR` latent vectors. Storage modes:
//!  - **Packed UINT-Q** (Q ∈ 6..8): codes bit-packed into one contiguous
//!    arena with a single per-buffer affine scale (`S_a,l` from PTQ
//!    calibration) — the paper's 4x/4.57x memory compression;
//!  - **F32**: the paper's FP32 baseline arm (Table II).
//!
//! Replacement follows AR1*'s external-memory policy: after learning event
//! number `e`, `h = max(1, N_LR / e)` random slots are overwritten by
//! random latents of the event — early events populate the memory quickly,
//! later ones displace ever less (reservoir-flavored), keeping the buffer
//! approximately balanced over everything seen.

use crate::quant::{pack_bits_into, packed_len, unpack_dequant_range, ActQuantizer};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
enum Storage {
    /// bit-packed codes, `slot * latent_elems` code offset per slot; `lut`
    /// is the buffer's dequantization table (`lut[q] = q * S_a`, exact for
    /// all Q <= 8), built once and fed to the fused unpack+dequant reader
    Packed { bits: u8, quant: ActQuantizer, lut: Box<[f32; 256]>, arena: Vec<u8> },
    F32 { arena: Vec<f32> },
}

#[derive(Clone, Debug)]
pub struct ReplayBuffer {
    capacity: usize,
    latent_elems: usize,
    labels: Vec<i32>,
    /// indices of filled slots, in fill order. `filled_slots.len()` is the
    /// occupancy; sampling draws from THIS list, never from raw slot
    /// numbers — writes via `event_update` on a partially-filled buffer
    /// are not prefix-contiguous, so `slot < len()` does NOT imply
    /// `labels[slot] != -1`.
    filled_slots: Vec<u32>,
    storage: Storage,
    /// reusable quantize scratch for the insert path (codes are packed
    /// straight into the arena slot — no packed scratch needed)
    scratch_codes: Vec<u8>,
}

impl ReplayBuffer {
    /// Quantized buffer: `bits` ∈ 1..=8, `a_max` = latent dynamic range.
    ///
    /// Slots must be byte-aligned: `(latent_elems * bits) % 8 == 0`. This
    /// is a hard assert (not a debug one): a misaligned latent size would
    /// make `write_slot` bit-pack across slot boundaries and silently
    /// corrupt neighboring slots in release builds. Every real split of
    /// both networks has a multiple-of-8 latent size, so Q ∈ 6..8 always
    /// aligns; arbitrary (elems, Q) combinations are rejected here.
    pub fn new_packed(capacity: usize, latent_elems: usize, bits: u8, a_max: f32) -> Self {
        assert!(
            (latent_elems * bits as usize) % 8 == 0,
            "replay slots must be byte-aligned: latent_elems={latent_elems} x Q={bits} \
             = {} bits is not a whole number of bytes",
            latent_elems * bits as usize
        );
        let quant = ActQuantizer::new(bits, a_max);
        let lut = Box::new(quant.lut());
        let arena = vec![0u8; packed_len(capacity * latent_elems, bits)];
        ReplayBuffer {
            capacity,
            latent_elems,
            labels: vec![-1; capacity],
            filled_slots: Vec::with_capacity(capacity),
            storage: Storage::Packed { bits, quant, lut, arena },
            scratch_codes: vec![0; latent_elems],
        }
    }

    /// FP32 baseline buffer (no compression).
    pub fn new_f32(capacity: usize, latent_elems: usize) -> Self {
        ReplayBuffer {
            capacity,
            latent_elems,
            labels: vec![-1; capacity],
            filled_slots: Vec::with_capacity(capacity),
            storage: Storage::F32 { arena: vec![0.0; capacity * latent_elems] },
            scratch_codes: Vec::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn latent_elems(&self) -> usize {
        self.latent_elems
    }

    pub fn len(&self) -> usize {
        self.filled_slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.filled_slots.is_empty()
    }

    /// Memory footprint of the stored latents (the Fig 6 x-axis, at mini
    /// scale): packed arena bytes or 4 B/elem for FP32.
    pub fn storage_bytes(&self) -> usize {
        match &self.storage {
            Storage::Packed { arena, .. } => arena.len(),
            Storage::F32 { arena } => arena.len() * 4,
        }
    }

    pub fn label(&self, slot: usize) -> i32 {
        self.labels[slot]
    }

    /// Write `latent` into `slot` (quantizing/packing as configured).
    pub fn write_slot(&mut self, slot: usize, latent: &[f32], label: i32) {
        assert!(slot < self.capacity, "slot {slot} out of range");
        assert!(label >= 0, "label must be non-negative (-1 marks empty slots)");
        assert_eq!(latent.len(), self.latent_elems, "latent size mismatch");
        match &mut self.storage {
            Storage::Packed { bits, quant, arena, .. } => {
                quant.quantize(latent, &mut self.scratch_codes);
                // pack the slot's codes straight into the arena — slots
                // are whole-byte aligned ((elems*bits)%8 == 0, enforced by
                // `new_packed`'s hard assert), so this write can never
                // bit-pack across a neighboring slot
                let bytes_per_slot = packed_len(self.latent_elems, *bits);
                let off = slot * bytes_per_slot;
                pack_bits_into(&self.scratch_codes, *bits, &mut arena[off..off + bytes_per_slot]);
            }
            Storage::F32 { arena } => {
                let off = slot * self.latent_elems;
                arena[off..off + self.latent_elems].copy_from_slice(latent);
            }
        }
        if self.labels[slot] == -1 {
            self.filled_slots.push(slot as u32);
        }
        self.labels[slot] = label;
    }

    /// Dequantize slot `slot` into `out` (the FP32 view the adaptive stage
    /// trains on: `S_a * code`, or the raw value in F32 mode). Packed
    /// slots go through the fused unpack+dequant reader: one pass over the
    /// arena straight into the caller's slice — no code scratch, no
    /// allocation, and a byte-indexed fast path at Q=8.
    pub fn read_slot_into(&self, slot: usize, out: &mut [f32]) {
        assert!(slot < self.capacity && self.labels[slot] != -1, "reading unfilled slot {slot}");
        assert_eq!(out.len(), self.latent_elems);
        match &self.storage {
            Storage::Packed { bits, lut, arena, .. } => {
                unpack_dequant_range(arena, *bits, slot * self.latent_elems, lut, out);
            }
            Storage::F32 { arena } => {
                let off = slot * self.latent_elems;
                out.copy_from_slice(&arena[off..off + self.latent_elems]);
            }
        }
    }

    /// Initial fill from the pre-deployment latents (paper: LRs sampled
    /// from the 3000 initial images). Takes `min(n, capacity)` distinct
    /// random rows — when the initial set is smaller than `N_LR` the
    /// buffer starts partially filled and later `event_update`s grow it
    /// (sampling stays sound either way: draws come from the filled-slot
    /// list, never from raw slot numbers).
    pub fn init_fill(&mut self, latents: &[f32], labels: &[i32], rng: &mut Rng) {
        let n = labels.len();
        assert_eq!(latents.len(), n * self.latent_elems);
        let take = n.min(self.capacity);
        let picks = rng.sample_indices(n, take);
        for (slot, &src) in picks.iter().enumerate() {
            self.write_slot(
                slot,
                &latents[src * self.latent_elems..(src + 1) * self.latent_elems],
                labels[src],
            );
        }
    }

    /// AR1*-style post-event update: overwrite `h = max(1, cap/event_idx)`
    /// random slots with random latents from the event (`event_idx` is
    /// 1-based). Returns `h`.
    pub fn event_update(
        &mut self,
        latents: &[f32],
        labels: &[i32],
        event_idx: usize,
        rng: &mut Rng,
    ) -> usize {
        assert!(event_idx >= 1);
        let n = labels.len();
        assert_eq!(latents.len(), n * self.latent_elems);
        let h = (self.capacity / event_idx).max(1).min(n).min(self.capacity);
        let dst = rng.sample_indices(self.capacity, h);
        let src = rng.sample_indices(n, h);
        for (&d, &s) in dst.iter().zip(&src) {
            self.write_slot(d, &latents[s * self.latent_elems..(s + 1) * self.latent_elems], labels[s]);
        }
        h
    }

    /// Sample `k` slots (with replacement, as the paper's minibatch mixer)
    /// dequantized into `out` (`k * latent_elems`), labels into
    /// `out_labels`. Read-only and allocation-free: every sampled slot is
    /// fused-dequantized straight into the caller's batch slice.
    ///
    /// Draws index into the filled-slot list, so holes left by
    /// `event_update` on a partially-filled buffer are never sampled
    /// (sampling a raw `slot < len()` would hit `label == -1` slots).
    pub fn sample_into(
        &self,
        k: usize,
        rng: &mut Rng,
        out: &mut [f32],
        out_labels: &mut [i32],
    ) {
        assert!(!self.filled_slots.is_empty(), "sampling from empty replay buffer");
        assert_eq!(out.len(), k * self.latent_elems);
        assert_eq!(out_labels.len(), k);
        for i in 0..k {
            let slot = self.filled_slots[rng.below(self.filled_slots.len())] as usize;
            out_labels[i] = self.labels[slot];
            let dst = &mut out[i * self.latent_elems..(i + 1) * self.latent_elems];
            self.read_slot_into(slot, dst);
        }
    }

    /// Per-class slot counts (buffer-balance diagnostics + tests).
    pub fn class_histogram(&self, n_classes: usize) -> Vec<usize> {
        let mut h = vec![0usize; n_classes];
        for &slot in &self.filled_slots {
            let l = self.labels[slot as usize];
            if l >= 0 && (l as usize) < n_classes {
                h[l as usize] += 1;
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ActQuantizer;
    use crate::util::prop;

    fn ramp(n: usize, base: f32) -> Vec<f32> {
        (0..n).map(|i| base + i as f32 * 0.01).collect()
    }

    #[test]
    fn fused_read_is_bit_exact_vs_quantizer_dequantize() {
        // the fused unpack+dequant path must produce the very same f32s
        // as quantize -> unpack -> ActQuantizer::dequantize, for every Q
        prop::check("replay fused read", 64, |rng| {
            let bits = prop::int_in(rng, 1, 8) as u8;
            let elems = 8 * prop::int_in(rng, 1, 16); // byte-aligned slots
            let a_max = 0.5 + rng.f32() * 4.0;
            let mut b = ReplayBuffer::new_packed(2, elems, bits, a_max);
            let lat = prop::vec_f32(rng, elems, 0.0, a_max);
            b.write_slot(0, &lat, 1);
            let mut fused = vec![0f32; elems];
            b.read_slot_into(0, &mut fused);
            let q = ActQuantizer::new(bits, a_max);
            let mut codes = Vec::new();
            q.quantize(&lat, &mut codes);
            let mut reference = vec![0f32; elems];
            q.dequantize(&codes, &mut reference);
            for (f, r) in fused.iter().zip(&reference) {
                assert_eq!(f.to_bits(), r.to_bits(), "bits={bits} a_max={a_max}");
            }
        });
    }

    #[test]
    fn write_read_roundtrip_f32_exact() {
        let mut b = ReplayBuffer::new_f32(4, 16);
        let lat = ramp(16, 0.5);
        b.write_slot(2, &lat, 7);
        let mut out = vec![0f32; 16];
        // slot 2 written but filled counts only non-(-1) labels; write slots 0,1 too
        b.write_slot(0, &lat, 1);
        b.write_slot(1, &lat, 2);
        b.read_slot_into(2, &mut out);
        assert_eq!(out, lat);
        assert_eq!(b.label(2), 7);
    }

    #[test]
    fn packed_roundtrip_error_bounded() {
        prop::check("replay packed roundtrip", 64, |rng| {
            let bits = prop::int_in(rng, 6, 8) as u8;
            let elems = 8 * prop::int_in(rng, 1, 32); // byte-aligned slots
            let a_max = 1.0 + rng.f32() * 4.0;
            let mut b = ReplayBuffer::new_packed(3, elems, bits, a_max);
            let lat = prop::vec_f32(rng, elems, 0.0, a_max);
            b.write_slot(0, &lat, 3);
            let mut out = vec![0f32; elems];
            b.read_slot_into(0, &mut out);
            let step = a_max / ((1u32 << bits) - 1) as f32;
            for (&x, &y) in lat.iter().zip(&out) {
                assert!((x - y).abs() <= step * (1.0 + 1e-5));
            }
        });
    }

    #[test]
    fn storage_bytes_match_compression() {
        let b8 = ReplayBuffer::new_packed(100, 1024, 8, 1.0);
        let b7 = ReplayBuffer::new_packed(100, 1024, 7, 1.0);
        let b6 = ReplayBuffer::new_packed(100, 1024, 6, 1.0);
        let f = ReplayBuffer::new_f32(100, 1024);
        assert_eq!(b8.storage_bytes(), 100 * 1024);
        assert_eq!(b7.storage_bytes(), 100 * 1024 * 7 / 8);
        assert_eq!(b6.storage_bytes(), 100 * 1024 * 6 / 8);
        assert_eq!(f.storage_bytes(), 100 * 1024 * 4);
    }

    #[test]
    fn init_fill_fills_and_respects_labels() {
        let mut rng = Rng::new(1);
        let elems = 8;
        let n = 50;
        let latents: Vec<f32> = (0..n * elems).map(|i| (i % 97) as f32 * 0.01).collect();
        let labels: Vec<i32> = (0..n as i32).map(|i| i % 4).collect();
        let mut b = ReplayBuffer::new_packed(20, elems, 8, 1.0);
        b.init_fill(&latents, &labels, &mut rng);
        assert_eq!(b.len(), 20);
        let hist = b.class_histogram(4);
        assert_eq!(hist.iter().sum::<usize>(), 20);
        assert!(hist.iter().all(|&c| c > 0), "all classes represented: {hist:?}");
    }

    #[test]
    fn event_update_h_decays() {
        let mut rng = Rng::new(2);
        let elems = 8;
        let mut b = ReplayBuffer::new_f32(64, elems);
        let latents = vec![0.25f32; 100 * elems];
        let labels = vec![5i32; 100];
        b.init_fill(&latents[..64 * elems], &labels[..64], &mut rng);
        let h1 = b.event_update(&latents, &labels, 1, &mut rng);
        let h4 = b.event_update(&latents, &labels, 4, &mut rng);
        let h100 = b.event_update(&latents, &labels, 100, &mut rng);
        assert_eq!(h1, 64);
        assert_eq!(h4, 16);
        assert_eq!(h100, 1);
    }

    #[test]
    fn event_update_inserts_new_class() {
        let mut rng = Rng::new(3);
        let elems = 8;
        let mut b = ReplayBuffer::new_packed(32, elems, 8, 1.0);
        let lat0 = vec![0.1f32; 40 * elems];
        let lab0 = vec![0i32; 40];
        b.init_fill(&lat0, &lab0, &mut rng);
        let lat1 = vec![0.9f32; 40 * elems];
        let lab1 = vec![1i32; 40];
        b.event_update(&lat1, &lab1, 2, &mut rng); // h = 16
        let hist = b.class_histogram(2);
        assert_eq!(hist[0] + hist[1], 32);
        assert_eq!(hist[1], 16);
    }

    #[test]
    fn sample_into_draws_valid() {
        let mut rng = Rng::new(4);
        let elems = 16;
        let mut b = ReplayBuffer::new_packed(10, elems, 7, 2.0);
        let latents: Vec<f32> = (0..10 * elems).map(|i| (i as f32 * 0.007) % 2.0).collect();
        let labels: Vec<i32> = (0..10).collect();
        b.init_fill(&latents, &labels, &mut rng);
        let k = 30;
        let mut out = vec![0f32; k * elems];
        let mut labs = vec![0i32; k];
        b.sample_into(k, &mut rng, &mut out, &mut labs);
        assert!(labs.iter().all(|&l| (0..10).contains(&l)));
        let step = 2.0 / 127.0f32;
        assert!(out.iter().all(|&v| v >= 0.0 && v <= 2.0 + step));
    }

    #[test]
    fn event_update_before_init_fill_leaves_no_sampling_holes() {
        // regression: event_update on a never-init_fill'ed buffer writes
        // non-contiguous slots; sampling used to draw raw `slot < filled`
        // indices and could land on label == -1 holes (panic in the packed
        // read path, silent skew otherwise)
        let mut rng = Rng::new(11);
        let elems = 8;
        let mut b = ReplayBuffer::new_packed(64, elems, 8, 1.0);
        let latents = vec![0.5f32; 20 * elems];
        let labels = vec![3i32; 20];
        // event 4 -> h = 16 random slots out of 64 (holes guaranteed)
        let h = b.event_update(&latents, &labels, 4, &mut rng);
        assert_eq!(h, 16);
        assert_eq!(b.len(), 16);
        let k = 200;
        let mut out = vec![0f32; k * elems];
        let mut labs = vec![-7i32; k];
        b.sample_into(k, &mut rng, &mut out, &mut labs);
        assert!(
            labs.iter().all(|&l| l == 3),
            "sampled a hole: labels {:?}",
            &labs[..8]
        );
    }

    #[test]
    fn partial_init_fill_supported() {
        // fewer initial latents than capacity: the buffer starts partially
        // filled and sampling draws only from the filled prefix
        let mut rng = Rng::new(12);
        let elems = 8;
        let mut b = ReplayBuffer::new_packed(32, elems, 8, 1.0);
        let latents: Vec<f32> = (0..10 * elems).map(|i| (i % 13) as f32 * 0.05).collect();
        let labels: Vec<i32> = (0..10).collect();
        b.init_fill(&latents, &labels, &mut rng);
        assert_eq!(b.len(), 10);
        let mut out = vec![0f32; 50 * elems];
        let mut labs = vec![0i32; 50];
        b.sample_into(50, &mut rng, &mut out, &mut labs);
        assert!(labs.iter().all(|&l| (0..10).contains(&l)));
        // growth continues through event updates
        b.event_update(&latents, &labels, 1, &mut rng);
        assert!(b.len() >= 10);
    }

    #[test]
    #[should_panic(expected = "byte-aligned")]
    fn misaligned_q6_slots_rejected() {
        // 10 elems x 6 bits = 60 bits: slots would straddle byte limits
        // and bit-pack into their neighbors — must be rejected up front
        let _ = ReplayBuffer::new_packed(4, 10, 6, 1.0);
    }

    #[test]
    #[should_panic(expected = "byte-aligned")]
    fn misaligned_q7_slots_rejected() {
        // 4 elems x 7 bits = 28 bits
        let _ = ReplayBuffer::new_packed(4, 4, 7, 1.0);
    }

    #[test]
    fn aligned_sub_byte_slots_accepted() {
        // (elems * Q) % 8 == 0 without elems % 8 == 0: still byte-aligned
        let b6 = ReplayBuffer::new_packed(4, 4, 6, 1.0); // 24 bits
        assert_eq!(b6.storage_bytes(), 4 * 3);
        let b7 = ReplayBuffer::new_packed(4, 16, 7, 1.0); // 112 bits
        assert_eq!(b7.storage_bytes(), 4 * 14);
    }

    #[test]
    #[should_panic(expected = "sampling from empty")]
    fn sampling_empty_panics() {
        let mut b = ReplayBuffer::new_f32(4, 8);
        let mut out = vec![0f32; 8];
        let mut labs = vec![0i32; 1];
        b.sample_into(1, &mut Rng::new(0), &mut out, &mut labs);
    }

    #[test]
    fn deterministic_under_seed() {
        let run = |seed| {
            let mut rng = Rng::new(seed);
            let elems = 8;
            let mut b = ReplayBuffer::new_packed(16, elems, 8, 1.0);
            let latents: Vec<f32> = (0..32 * elems).map(|i| (i % 13) as f32 * 0.05).collect();
            let labels: Vec<i32> = (0..32).map(|i| (i % 3) as i32).collect();
            b.init_fill(&latents, &labels, &mut rng);
            let mut out = vec![0f32; 4 * elems];
            let mut labs = vec![0i32; 4];
            b.sample_into(4, &mut rng, &mut out, &mut labs);
            (out, labs)
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9).1, run(10).1);
    }
}
