//! L3 coordinator — the paper's system contribution, in rust.
//!
//! [`replay`] is the quantized latent-replay memory, [`batcher`] the
//! new/replay mini-batch mixer, [`protocol`] the NICv2-mini event schedule,
//! [`trainer`] the per-event training engine over the AOT modules, and
//! [`metrics`] the run bookkeeping. [`run_protocol`] wires them into a full
//! continual-learning deployment: one call = one paper-style run.

pub mod batcher;
pub mod metrics;
pub mod protocol;
pub mod replay;
pub mod trainer;

use std::time::Instant;

use anyhow::Result;

pub use batcher::{Batcher, FrozenCoalescer};
pub use metrics::{EventRecord, LatencySummary, RunResult};
pub use protocol::Event;
pub use trainer::{
    eval_on_latents, train_event_on_latents, CLConfig, EvalLatentCache, EventStats, Session,
};

use crate::runtime::{Backend, Dataset};
use crate::util::rng::Rng;

/// Options for a full protocol run.
#[derive(Clone, Copy, Debug)]
pub struct RunOptions {
    /// evaluate every N events (0 = only initial + final)
    pub eval_every: usize,
    /// cap the number of events (0 = full schedule) — fast profiles
    pub max_events: usize,
    /// print per-event progress
    pub verbose: bool,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions { eval_every: 8, max_events: 0, verbose: false }
    }
}

/// Run the full NICv2-mini protocol for one configuration, on any
/// [`Backend`] (PJRT over artifacts, or the native kernel engine).
pub fn run_protocol(
    be: &dyn Backend,
    ds: &Dataset,
    cfg: CLConfig,
    opts: RunOptions,
) -> Result<RunResult> {
    run_protocol_cached(be, ds, cfg, opts, None)
}

/// [`run_protocol`] with a shared test-latent cache — the figure harness
/// passes one cache across a whole sweep (the frozen stage is immutable,
/// so test latents are identical for every run of the same split/mode).
pub fn run_protocol_cached(
    be: &dyn Backend,
    ds: &Dataset,
    cfg: CLConfig,
    opts: RunOptions,
    cache: Option<&EvalLatentCache>,
) -> Result<RunResult> {
    let t0 = Instant::now();
    let mut session = Session::new(be, ds, cfg)?;
    if let Some(c) = cache {
        session.use_eval_cache(ds, c)?;
    }
    let mut schedule_rng = Rng::new(cfg.seed.wrapping_mul(0xA5A5_A5A5).wrapping_add(1));
    let mut schedule = protocol::build_schedule(&be.manifest().protocol, &mut schedule_rng);
    if opts.max_events > 0 && schedule.len() > opts.max_events {
        schedule.truncate(opts.max_events);
    }

    let initial_acc = session.evaluate(ds)?;
    if opts.verbose {
        println!("[run {}] initial acc {:.3}", cfg.label(), initial_acc);
    }

    let mut result = RunResult {
        label: cfg.label(),
        initial_acc,
        lr_storage_bytes: session.replay.storage_bytes(),
        ..Default::default()
    };

    let total = schedule.len();
    for (i, ev) in schedule.iter().enumerate() {
        let te = Instant::now();
        let stats = session.run_event(ds, ev.class, ev.session)?;
        let need_eval = (opts.eval_every > 0 && (i + 1) % opts.eval_every == 0)
            || i + 1 == total;
        let test_acc = if need_eval { Some(session.evaluate(ds)?) } else { None };
        if opts.verbose {
            if let Some(acc) = test_acc {
                println!(
                    "[run {}] event {}/{} class {} sess {} loss {:.3} -> acc {:.3}",
                    cfg.label(), i + 1, total, ev.class, ev.session, stats.mean_loss, acc
                );
            }
        }
        result.events.push(EventRecord {
            event_idx: i + 1,
            class: ev.class,
            session: ev.session,
            new_class: ev.new_class,
            steps: stats.steps,
            mean_loss: stats.mean_loss,
            train_acc: stats.train_acc,
            replaced: stats.replaced,
            test_acc,
            wall: te.elapsed(),
        });
    }

    result.final_acc = result
        .events
        .iter()
        .rev()
        .find_map(|e| e.test_acc)
        .unwrap_or(initial_acc);
    result.total_wall = t0.elapsed();
    Ok(result)
}
