//! Cluster-DMA transfer model — the Fig. 9 axis.
//!
//! Transfers are 2D-strided AXI bursts; the model charges `bytes * 8 / bw`
//! cycles per direction plus a per-transfer setup cost. Half-duplex DMAs
//! (the Fig. 9 sweep assumption) serialize reads and writes on one
//! channel; VEGA's is full duplex at 64 bit/cyc each way.

use super::targets::HwConfig;

/// Per-transfer programming/setup cycles (descriptor write + start).
pub const DMA_SETUP_CYCLES: f64 = 40.0;

/// Cycles for one tile's input transfer (L2 -> L1).
pub fn read_cycles(hw: &HwConfig, bytes: usize) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    bytes as f64 * 8.0 / hw.dma_read_bits_per_cyc + DMA_SETUP_CYCLES
}

/// Cycles for one tile's output transfer (L1 -> L2).
pub fn write_cycles(hw: &HwConfig, bytes: usize) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    bytes as f64 * 8.0 / hw.dma_write_bits_per_cyc + DMA_SETUP_CYCLES
}

/// Total DMA occupancy for one tile (in + out). Full duplex overlaps the
/// two directions; half duplex serializes them.
pub fn tile_transfer_cycles(hw: &HwConfig, in_bytes: usize, out_bytes: usize) -> f64 {
    let r = read_cycles(hw, in_bytes);
    let w = write_cycles(hw, out_bytes);
    if hw.full_duplex {
        r.max(w)
    } else {
        r + w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw(bw: f64, duplex: bool) -> HwConfig {
        HwConfig {
            cores: 8,
            l1_bytes: 128 * 1024,
            dma_read_bits_per_cyc: bw,
            dma_write_bits_per_cyc: bw,
            full_duplex: duplex,
        }
    }

    #[test]
    fn bandwidth_scaling() {
        let h8 = hw(8.0, false);
        let h64 = hw(64.0, false);
        let slow = read_cycles(&h8, 4096);
        let fast = read_cycles(&h64, 4096);
        // 8x the bandwidth -> ~8x fewer cycles (minus setup)
        assert!((slow - DMA_SETUP_CYCLES) / (fast - DMA_SETUP_CYCLES) > 7.9);
    }

    #[test]
    fn duplex_overlap() {
        let half = hw(64.0, false);
        let full = hw(64.0, true);
        let t_half = tile_transfer_cycles(&half, 4096, 4096);
        let t_full = tile_transfer_cycles(&full, 4096, 4096);
        assert!((t_half / t_full - 2.0).abs() < 0.1);
    }

    #[test]
    fn zero_bytes_costs_nothing() {
        let h = hw(64.0, true);
        assert_eq!(read_cycles(&h, 0), 0.0);
        assert_eq!(tile_transfer_cycles(&h, 0, 0), 0.0);
    }

    #[test]
    fn infinite_bw_is_setup_only() {
        let h = hw(f64::INFINITY, true);
        assert_eq!(read_cycles(&h, 1_000_000), DMA_SETUP_CYCLES);
    }
}
