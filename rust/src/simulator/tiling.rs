//! The L1 tile solver (paper §IV-B, Fig. 4).
//!
//! Every layer-pass is a matmul `[M, K] x [K, N]` (after im2col for
//! convolutions). Operands live in L2; the cluster DMA copies tiles into
//! L1, double-buffered, so a tile set (x, w, out [, im2col scratch]) may
//! occupy at most **half** of L1. We tile along M (output rows), keeping
//! the full K inner loop resident — exactly the paper's scheme, where a
//! bigger L1 buys a longer inner loop.

use super::kernels::{k_inner_for, Pass};
use crate::models::{LayerDesc, LayerKind};

/// Matmul geometry of one (layer, pass, batch) — before tiling.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MatmulGeom {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// extra L1 floats per output row (im2col scratch for DW/C3 tiles)
    pub scratch_per_row: usize,
}

/// Map a layer + pass + batch to its matmul geometry.
///
/// FW:      [B*Ho*Wo, Cin_eff] x [Cin_eff, Cout]
/// BW-ERR:  [B*Ho*Wo, Cout]    x [Cout, Cin_eff]
/// BW-GRAD: [Cin_eff, B*Ho*Wo] x [B*Ho*Wo, Cout]   (reduction over rows)
/// DW layers reduce over their 9 taps per channel.
pub fn matmul_geom(layer: &LayerDesc, pass: Pass, batch: usize) -> MatmulGeom {
    let ho = layer.hw_out();
    let rows = batch * ho * ho;
    match layer.kind {
        LayerKind::DepthWise => {
            // per-channel 3x3: M = rows, N = C, K = 9 (+ im2col scratch)
            MatmulGeom { m: rows, n: layer.cout, k: 9, scratch_per_row: 9 }
        }
        LayerKind::Conv3x3 => {
            let k = 9 * layer.cin;
            match pass {
                Pass::Fw => MatmulGeom { m: rows, n: layer.cout, k, scratch_per_row: k },
                Pass::BwErr => MatmulGeom { m: rows, n: k, k: layer.cout, scratch_per_row: 0 },
                Pass::BwGrad => MatmulGeom { m: k, n: layer.cout, k: rows, scratch_per_row: 0 },
            }
        }
        LayerKind::PointWise => match pass {
            Pass::Fw => MatmulGeom { m: rows, n: layer.cout, k: layer.cin, scratch_per_row: 0 },
            Pass::BwErr => MatmulGeom { m: rows, n: layer.cin, k: layer.cout, scratch_per_row: 0 },
            Pass::BwGrad => MatmulGeom { m: layer.cin, n: layer.cout, k: rows, scratch_per_row: 0 },
        },
        LayerKind::Linear => match pass {
            Pass::Fw => MatmulGeom { m: batch, n: layer.cout, k: layer.cin, scratch_per_row: 0 },
            Pass::BwErr => MatmulGeom { m: batch, n: layer.cin, k: layer.cout, scratch_per_row: 0 },
            Pass::BwGrad => {
                MatmulGeom { m: layer.cin, n: layer.cout, k: batch, scratch_per_row: 0 }
            }
        },
    }
}

/// The solved tile dimensions `(tm, tn, tk)` of a matmul pass.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileDims {
    pub tm: usize,
    pub tn: usize,
    pub tk: usize,
}

impl TileDims {
    /// f32 elements one (x, w, out [, scratch]) tile set occupies in L1.
    pub fn floats(&self, scratch_per_row: usize) -> usize {
        self.tm * self.tk + self.tk * self.tn + self.tm * self.tn + self.tm * scratch_per_row
    }
}

/// One L1-resident tile of work.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Tile {
    pub rows: usize,
    pub macs: u64,
    /// bytes DMA'd L2 -> L1 for this tile (x block + weight block)
    pub in_bytes: usize,
    /// bytes DMA'd L1 -> L2 (output block; 0 for partial-K tiles, whose
    /// accumulator stays resident until the K loop finishes)
    pub out_bytes: usize,
}

#[derive(Clone, Debug)]
pub struct TileSchedule {
    pub geom: MatmulGeom,
    pub dims: TileDims,
    pub n_tiles: usize,
    pub tiles: Vec<Tile>,
    /// K length the kernel model should use (inner loop) — the FORWARD
    /// pass's resident reduction length; backward passes inherit it and
    /// apply the paper's reuse factors instead (see kernels.rs)
    pub k_inner: usize,
}

/// Solve `(tm, tn, tk)` under `l1_bytes` with double buffering
/// (tile set <= L1/2): keep the reduction (`tk`) as long as possible —
/// the paper's "bigger L1 = longer inner loop" — then give output
/// channels (`tn`) and rows (`tm`) the rest.
pub fn solve_tile(geom: &MatmulGeom, l1_bytes: usize) -> TileDims {
    let budget = l1_bytes / 2 / 4; // floats, double-buffered
    let mut tk = geom.k;
    let mut tn = geom.n;
    // minimum viable set at tm=1 must fit: tk + tk*tn + tn + scratch
    let fits = |tm: usize, tn: usize, tk: usize| {
        TileDims { tm, tn, tk }.floats(geom.scratch_per_row) <= budget
    };
    while !fits(1, tn, tk) && tn > 1 {
        tn = (tn + 1) / 2;
    }
    while !fits(1, tn, tk) && tk > 16 {
        tk = (tk + 1) / 2;
    }
    // rows: whatever is left
    let mut tm = geom.m;
    while !fits(tm, tn, tk) && tm > 1 {
        tm = (tm + 1) / 2;
    }
    TileDims { tm, tn, tk }
}

/// Build the full tile schedule for a layer-pass.
pub fn schedule_layer(
    layer: &LayerDesc,
    pass: Pass,
    batch: usize,
    l1_bytes: usize,
) -> TileSchedule {
    let geom = matmul_geom(layer, pass, batch);
    let dims = solve_tile(&geom, l1_bytes);
    let (m, n, k) = (geom.m, geom.n, geom.k);
    let div = |a: usize, b: usize| (a + b - 1) / b;
    let (nm, nn, nk) = (div(m, dims.tm), div(n, dims.tn), div(k, dims.tk));

    let mut tiles = Vec::with_capacity(nm * nn * nk);
    for im in 0..nm {
        let rows = dims.tm.min(m - im * dims.tm);
        for in_ in 0..nn {
            let cols = dims.tn.min(n - in_ * dims.tn);
            for ik in 0..nk {
                let red = dims.tk.min(k - ik * dims.tk);
                tiles.push(Tile {
                    rows,
                    macs: rows as u64 * cols as u64 * red as u64,
                    in_bytes: (rows * red + red * cols) * 4,
                    // the output block writes back once, after the last
                    // K-chunk accumulates
                    out_bytes: if ik == nk - 1 { rows * cols * 4 } else { 0 },
                });
            }
        }
    }

    // the kernel-model inner loop uses the FORWARD reduction length at
    // this L1 size (backward factors are relative to FW — kernels.rs)
    let fw_geom = matmul_geom(layer, Pass::Fw, batch);
    let fw_dims = solve_tile(&fw_geom, l1_bytes);
    let k_inner = k_inner_for(layer.kind, Pass::Fw, fw_dims.tk, fw_geom.n, fw_dims.tm);

    TileSchedule { geom, dims, n_tiles: tiles.len(), tiles, k_inner }
}

impl TileSchedule {
    pub fn total_macs(&self) -> u64 {
        self.tiles.iter().map(|t| t.macs).sum()
    }

    pub fn total_in_bytes(&self) -> usize {
        self.tiles.iter().map(|t| t.in_bytes).sum()
    }

    pub fn total_out_bytes(&self) -> usize {
        self.tiles.iter().map(|t| t.out_bytes).sum()
    }

    /// L1 bytes one buffered tile set occupies (must be <= L1/2).
    pub fn tile_set_bytes(&self) -> usize {
        self.dims.floats(self.geom.scratch_per_row) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mobilenet_v1_128;
    use crate::util::prop;

    #[test]
    fn tiles_cover_all_macs_exactly() {
        let net = mobilenet_v1_128();
        for l in [0usize, 19, 22, 23, 27] {
            let layer = net.layer(l);
            for pass in Pass::all() {
                let s = schedule_layer(layer, pass, 128, 128 * 1024);
                // total tiled MACs == batch * layer MACs (fw geometry);
                // backward geometries transpose but preserve the product
                assert_eq!(
                    s.total_macs(),
                    128 * layer.macs(),
                    "layer {l} {pass:?}"
                );
            }
        }
    }

    #[test]
    fn double_buffer_constraint_holds() {
        let net = mobilenet_v1_128();
        for l in 0..net.layers.len() {
            for pass in Pass::all() {
                for l1 in [128 * 1024, 256 * 1024, 512 * 1024] {
                    let s = schedule_layer(net.layer(l), pass, 128, l1);
                    if s.dims.tm > 1 {
                        assert!(
                            s.tile_set_bytes() <= l1 / 2,
                            "layer {l} {pass:?} l1 {l1}: {} > {}",
                            s.tile_set_bytes(),
                            l1 / 2
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bigger_l1_means_fewer_tiles() {
        let net = mobilenet_v1_128();
        let layer = net.layer(22); // PW 8x8x512->512, 1.25 MB of operands
        let small = schedule_layer(layer, Pass::Fw, 128, 128 * 1024);
        // (sanity on the paper's example: PW #22 tensors exceed 128 kB L1)
        assert!(small.n_tiles > 1, "PW22 must need tiling at 128 kB");
        let big = schedule_layer(layer, Pass::Fw, 128, 512 * 1024);
        assert!(big.n_tiles <= small.n_tiles);
        assert!(
            big.dims.floats(big.geom.scratch_per_row)
                >= small.dims.floats(small.geom.scratch_per_row)
        );
    }

    #[test]
    fn paper_example_pw22_needs_tiling() {
        // §IV-B: "the tensors of the PW layer #22 occupy 1.25 MB"
        let net = mobilenet_v1_128();
        let layer = net.layer(22);
        let total_bytes =
            (layer.in_elems() + layer.out_elems() + layer.n_weights()) * 4;
        assert!((1_200_000..1_400_000).contains(&total_bytes), "{total_bytes}");
    }

    #[test]
    fn geometry_transposes_are_consistent() {
        prop::check("tiling geom", 64, |rng| {
            let net = mobilenet_v1_128();
            let l = prop::int_in(rng, 1, net.layers.len() - 1);
            let batch = [1usize, 8, 21, 128][rng.below(4)];
            let layer = net.layer(l);
            let fw = matmul_geom(layer, Pass::Fw, batch);
            let be = matmul_geom(layer, Pass::BwErr, batch);
            let bg = matmul_geom(layer, Pass::BwGrad, batch);
            let p = |g: MatmulGeom| g.m as u64 * g.n as u64 * g.k as u64;
            assert_eq!(p(fw), p(be), "layer {l}");
            assert_eq!(p(fw), p(bg), "layer {l}");
        });
    }

    #[test]
    fn single_row_tiles_when_l1_tiny() {
        let net = mobilenet_v1_128();
        let s = schedule_layer(net.layer(22), Pass::Fw, 128, 4 * 1024);
        assert!(s.dims.tm <= 2, "tm {}", s.dims.tm);
        assert!(s.n_tiles > 1000);
        assert!(s.tile_set_bytes() <= 2 * 1024);
    }
}
