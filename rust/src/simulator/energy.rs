//! Energy + battery-lifetime model (Fig. 10 and the abstract's headline
//! "lifetime of 535 h when learning a mini-batch once per minute").
//!
//! Assumptions follow §V-E: active power only while a learning event runs,
//! zero otherwise ("we assumed no extra energy consumption for the
//! remaining time"), a 3300 mAh battery at a nominal 3.7 V.

use super::executor::{event_seconds, EventSpec};
use super::targets::{HwConfig, TargetSpec};
use crate::models::NetDesc;

pub const BATTERY_MAH: f64 = 3300.0;
pub const BATTERY_V: f64 = 3.7;

/// Battery capacity in joules.
pub fn battery_capacity_j() -> f64 {
    BATTERY_MAH / 1000.0 * BATTERY_V * 3600.0
}

/// Energy of one learning event (J).
pub fn event_energy_j(
    t: &TargetSpec,
    hw: &HwConfig,
    net: &NetDesc,
    first_adaptive: usize,
    ev: &EventSpec,
) -> f64 {
    t.energy_j(event_seconds(t, hw, net, first_adaptive, ev))
}

/// Battery lifetime (hours) at `events_per_hour` learning events, assuming
/// idle consumes nothing. Returns `None` when the duty cycle is infeasible
/// (events take longer than the hour allows).
pub fn lifetime_hours(
    t: &TargetSpec,
    hw: &HwConfig,
    net: &NetDesc,
    first_adaptive: usize,
    ev: &EventSpec,
    events_per_hour: f64,
) -> Option<f64> {
    let secs = event_seconds(t, hw, net, first_adaptive, ev);
    if secs * events_per_hour > 3600.0 {
        return None; // can't sustain this rate
    }
    let joules_per_hour = event_energy_j(t, hw, net, first_adaptive, ev) * events_per_hour;
    Some(battery_capacity_j() / joules_per_hour)
}

/// Max sustainable learning-event rate (events/hour).
pub fn max_rate_per_hour(
    t: &TargetSpec,
    hw: &HwConfig,
    net: &NetDesc,
    first_adaptive: usize,
    ev: &EventSpec,
) -> f64 {
    3600.0 / event_seconds(t, hw, net, first_adaptive, ev)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mobilenet_v1_128;
    use crate::simulator::targets::{stm32l4, vega};

    #[test]
    fn capacity_is_44kj() {
        assert!((battery_capacity_j() - 43_956.0).abs() < 1.0);
    }

    #[test]
    fn lifetime_monotone_in_rate() {
        let v = vega();
        let net = mobilenet_v1_128();
        let ev = EventSpec::paper();
        let l1 = lifetime_hours(&v, &v.default_hw, &net, 27, &ev, 1.0).unwrap();
        let l60 = lifetime_hours(&v, &v.default_hw, &net, 27, &ev, 60.0).unwrap();
        assert!(l1 > l60);
        assert!((l1 / l60 - 60.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_rate_detected() {
        let v = vega();
        let net = mobilenet_v1_128();
        let ev = EventSpec::paper();
        // l=20 events take O(10^3) s; thousands/hour is impossible
        assert!(lifetime_hours(&v, &v.default_hw, &net, 20, &ev, 10_000.0).is_none());
    }

    #[test]
    fn vega_outlives_stm32_at_same_rate() {
        // paper: "at the same learning event rate, the battery lifetime of
        // VEGA is 20x higher" (1/hour, last layer)
        let v = vega();
        let s = stm32l4();
        let net = mobilenet_v1_128();
        let ev = EventSpec::paper();
        let lv = lifetime_hours(&v, &v.default_hw, &net, 27, &ev, 1.0).unwrap();
        let ls = lifetime_hours(&s, &s.default_hw, &net, 27, &ev, 1.0).unwrap();
        let ratio = lv / ls;
        assert!((10.0..80.0).contains(&ratio), "lifetime ratio {ratio}");
    }

    #[test]
    fn once_a_minute_headline_order() {
        // abstract: learning one mini-batch per minute (last layer) gives a
        // lifetime of hundreds of hours
        let v = vega();
        let net = mobilenet_v1_128();
        // one mini-batch ~ one 14th of a full event
        let ev = EventSpec { batch: 128, iters: 1, new_images: 21 };
        let l = lifetime_hours(&v, &v.default_hw, &net, 27, &ev, 60.0).unwrap();
        assert!((100.0..20_000.0).contains(&l), "lifetime {l} h");
    }
}
