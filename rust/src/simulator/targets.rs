//! Target descriptions + calibration constants (DESIGN.md §7).
//!
//! Every constant here is either a datasheet/paper value (clock, power,
//! memory sizes, DMA width) or a calibrated µarch coefficient chosen once
//! to land the paper's anchor measurements; nothing else in the simulator
//! has tunable numbers.

/// Cluster-level hardware knobs (the Fig. 8/9 sweep axes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HwConfig {
    pub cores: usize,
    pub l1_bytes: usize,
    /// cluster DMA read bandwidth, bits per cycle
    pub dma_read_bits_per_cyc: f64,
    /// cluster DMA write bandwidth, bits per cycle
    pub dma_write_bits_per_cyc: f64,
    /// full duplex: reads and writes overlap (VEGA); half duplex shares one
    /// channel (the Fig. 9 sweep assumption)
    pub full_duplex: bool,
}

/// ISA/µarch cycle coefficients of the FP32 training kernels.
#[derive(Clone, Copy, Debug)]
pub struct IsaModel {
    /// asymptotic cycles per FP32 MAC on one core (fmadd + loads + loop)
    pub c_mac: f64,
    /// per-output-element overhead cycles, amortized over the K inner loop
    /// (pointer setup, store, accumulator spill, HW-loop setup)
    pub c_outer: f64,
    /// per-tile prologue cycles (I$ warm-up, barrier, DMA wait epilogue)
    pub prologue: f64,
    /// depthwise asymptotic cycles/MAC (short 3x3 inner loop, filter-only
    /// reuse — §V-C)
    pub dw_c_mac: f64,
    /// software im2col latency as a fraction of the DW FW kernel latency
    /// (paper: "up to 70%"); DMA-assisted im2col removes it
    pub im2col_ratio: f64,
    /// BW-ERR MAC/cyc relative to FW (paper: -22%)
    pub bw_err_factor: f64,
    /// BW-GRAD MAC/cyc relative to FW (paper: -46%)
    pub bw_grad_factor: f64,
    /// parallel-efficiency contention: eff(n) = 1 / (1 + alpha * (n - 1))
    pub contention_alpha: f64,
    /// cluster-wide fmadd ceiling (shared FPUs), MAC/cyc
    pub fpu_ceiling: f64,
    /// INT-8 inference throughput per core (SIMD), MAC/cyc — frozen stage
    pub int8_macs_per_cyc_core: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct TargetSpec {
    pub name: &'static str,
    pub freq_hz: f64,
    /// average active power at full load, watts
    pub power_w: f64,
    pub isa: IsaModel,
    pub default_hw: HwConfig,
    /// has a cluster DMA with 2D strided access (tiling overlap + im2col)
    pub cluster_dma: bool,
}

impl TargetSpec {
    /// Parallel efficiency for `n` cores (TCDM banking conflicts + I$).
    pub fn parallel_eff(&self, cores: usize) -> f64 {
        1.0 / (1.0 + self.isa.contention_alpha * (cores.saturating_sub(1)) as f64)
    }

    pub fn seconds(&self, cycles: f64) -> f64 {
        cycles / self.freq_hz
    }

    pub fn energy_j(&self, seconds: f64) -> f64 {
        self.power_w * seconds
    }
}

/// VEGA (PULP, GF 22nm): 8+1 RV32IMCF-Xpulpv2 cores, 4 shared FPUs,
/// 128 kB L1 TCDM, 1.5 MB L2, full-duplex cluster DMA @64 bit/cyc each
/// way, 375 MHz, 62 mW average at full load (paper §V-D).
pub fn vega() -> TargetSpec {
    TargetSpec {
        name: "VEGA",
        freq_hz: 375e6,
        power_w: 0.062,
        isa: IsaModel {
            // calibrated: single-core 512kB-tile PW FW ~ 0.265 MAC/cyc and
            // 8-core peak 1.91 MAC/cyc (paper Fig. 8), +11% from 128->512 kB
            c_mac: 3.64,
            c_outer: 257.0,
            prologue: 600.0,
            // 8 cores * eff ~ 1.0 MAC/cyc with DMA-im2col (paper §V-C)
            dw_c_mac: 7.2,
            im2col_ratio: 0.7,
            bw_err_factor: 0.78,
            bw_grad_factor: 0.54,
            // eff(8) ~ 0.9 -> parallel speed-up 7.2x (paper)
            contention_alpha: 0.0159,
            fpu_ceiling: 4.0,
            // frozen INT-8 stage via DORY-style SIMD kernels
            int8_macs_per_cyc_core: 1.05,
        },
        default_hw: HwConfig {
            cores: 8,
            l1_bytes: 128 * 1024,
            dma_read_bits_per_cyc: 64.0,
            dma_write_bits_per_cyc: 64.0,
            full_duplex: true,
        },
        cluster_dma: true,
    }
}

/// STM32L476RG: Cortex-M4F @80 MHz, single core, 96 kB SRAM, no cluster
/// DMA, no fused MAC in the FP32 loop the paper measured (9-instruction
/// inner loop vs VEGA's 4).
pub fn stm32l4() -> TargetSpec {
    TargetSpec {
        name: "STM32L4",
        freq_hz: 80e6,
        power_w: 0.030,
        isa: IsaModel {
            c_mac: 9.3,
            c_outer: 40.0,
            prologue: 200.0,
            dw_c_mac: 14.0,
            im2col_ratio: 0.7,
            bw_err_factor: 0.85,
            bw_grad_factor: 0.65,
            contention_alpha: 0.0,
            fpu_ceiling: 1.0,
            int8_macs_per_cyc_core: 0.35,
        },
        default_hw: HwConfig {
            cores: 1,
            l1_bytes: 96 * 1024,
            // paper: "latency measurement of the STM32L4 does not account
            // for tiling overheads" — model it as compute-only
            dma_read_bits_per_cyc: f64::INFINITY,
            dma_write_bits_per_cyc: f64::INFINITY,
            full_duplex: true,
        },
        cluster_dma: false,
    }
}

/// Snapdragon 845 (OnePlus 6): the paper only uses published numbers —
/// 502 ms for their demo learning event, ~4 W power envelope.
pub fn snapdragon845() -> TargetSpec {
    TargetSpec {
        name: "Snapdragon845",
        freq_hz: 2.8e9,
        power_w: 4.0,
        isa: IsaModel {
            c_mac: 0.25, // wide NEON/SMT envelope, not modeled in detail
            c_outer: 16.0,
            prologue: 1000.0,
            dw_c_mac: 0.5,
            im2col_ratio: 0.2,
            bw_err_factor: 0.9,
            bw_grad_factor: 0.8,
            contention_alpha: 0.05,
            fpu_ceiling: 16.0,
            int8_macs_per_cyc_core: 4.0,
        },
        default_hw: HwConfig {
            cores: 4,
            l1_bytes: 2 * 1024 * 1024,
            dma_read_bits_per_cyc: f64::INFINITY,
            dma_write_bits_per_cyc: f64::INFINITY,
            full_duplex: true,
        },
        cluster_dma: false,
    }
}

/// Published anchor: Pellegrini et al.'s demo event on the Snapdragon 845
/// (500 LRs, last layer only, 8 epochs) measured 502 ms.
pub const SNAPDRAGON_EVENT_SECONDS: f64 = 0.502;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vega_parallel_speedup_anchor() {
        let v = vega();
        let speedup8 = 8.0 * v.parallel_eff(8);
        assert!((speedup8 - 7.2).abs() < 0.15, "8-core speed-up {speedup8}");
        assert!(v.parallel_eff(1) == 1.0);
        assert!(v.parallel_eff(2) > v.parallel_eff(4));
    }

    #[test]
    fn clock_ratio_anchor() {
        // paper: VEGA clock 4.7x the STM32L4's
        let r = vega().freq_hz / stm32l4().freq_hz;
        assert!((r - 4.69).abs() < 0.05, "{r}");
    }

    #[test]
    fn inner_loop_instruction_ratio() {
        // paper: 4 vs 9 instructions -> 2.25x; our asymptotic c_mac ratio
        let r = stm32l4().isa.c_mac / vega().isa.c_mac;
        assert!((2.0..3.0).contains(&r), "instr ratio {r}");
    }

    #[test]
    fn energy_model_basics() {
        let v = vega();
        let t = v.seconds(375e6);
        assert!((t - 1.0).abs() < 1e-9);
        assert!((v.energy_j(10.0) - 0.62).abs() < 1e-9);
    }
}
