//! Roll-ups: tile -> layer-pass -> adaptive stage -> learning event.
//!
//! Double-buffered execution (paper Fig. 4): while the cores compute tile
//! `i`, the DMA moves tile `i+1`; per-tile time is `max(compute, dma)`
//! plus a small switch cost, with the first transfer exposed. The paper
//! measures ~7% tiling overhead over single-tile compute on VEGA — the
//! integration tests assert our model lands in that range.

use super::dma;
use super::kernels::{tile_cycles, Pass};
use super::targets::{HwConfig, TargetSpec};
use super::tiling::schedule_layer;
use crate::models::{LayerDesc, LayerKind, NetDesc};

/// Per-tile buffer-switch / synchronization cost.
pub const TILE_SWITCH_CYCLES: f64 = 120.0;

/// Cycles for one layer-pass over a batch, tiled + double-buffered.
pub fn layer_pass_cycles(
    t: &TargetSpec,
    hw: &HwConfig,
    layer: &LayerDesc,
    pass: Pass,
    batch: usize,
) -> f64 {
    let sched = schedule_layer(layer, pass, batch, hw.l1_bytes);
    // DW tiles get DMA-side im2col only when a cluster DMA exists
    let dma_im2col = t.cluster_dma && layer.kind == LayerKind::DepthWise;
    let mut total = 0.0;
    let mut prev_dma = 0.0;
    for (i, tile) in sched.tiles.iter().enumerate() {
        let compute = tile_cycles(
            t,
            hw.cores,
            layer.kind,
            pass,
            tile.macs,
            sched.k_inner,
            dma_im2col,
        );
        let transfer = if t.cluster_dma {
            dma::tile_transfer_cycles(hw, tile.in_bytes, tile.out_bytes)
        } else {
            0.0
        };
        if i == 0 {
            // first tile's input transfer is exposed
            total += if t.cluster_dma { dma::read_cycles(hw, tile.in_bytes) } else { 0.0 };
        }
        // steady state: compute overlaps the *next* tile's transfer; model
        // as max(compute_i, transfer_{i-1 -> i}) per step
        total += compute.max(prev_dma) + TILE_SWITCH_CYCLES;
        prev_dma = transfer;
    }
    // last tile's output write-back is exposed
    if t.cluster_dma {
        if let Some(last) = sched.tiles.last() {
            total += dma::write_cycles(hw, last.out_bytes);
        }
    }
    total
}

/// Full training cost of one layer for one mini-batch: FW + BW-ERR +
/// BW-GRAD. `first_adaptive` layers skip BW-ERR propagation *below*
/// themselves — the paper likewise omits the error step of the first
/// retrained layer (nothing upstream needs the gradient).
pub fn layer_training_cycles(
    t: &TargetSpec,
    hw: &HwConfig,
    layer: &LayerDesc,
    batch: usize,
    skip_bw_err: bool,
) -> f64 {
    let mut c = layer_pass_cycles(t, hw, layer, Pass::Fw, batch);
    if !skip_bw_err {
        c += layer_pass_cycles(t, hw, layer, Pass::BwErr, batch);
    }
    c += layer_pass_cycles(t, hw, layer, Pass::BwGrad, batch);
    c
}

/// The paper's learning-event workload (§V-A/V-D): `iters` mini-batches of
/// `batch` latent samples through the adaptive stage (training), plus
/// `new_images` INT-8 frozen-stage forwards.
#[derive(Clone, Copy, Debug)]
pub struct EventSpec {
    pub batch: usize,
    pub iters: usize,
    pub new_images: usize,
}

impl EventSpec {
    /// The learning event Table IV's magnitudes correspond to (§V-E): one
    /// mini-batch of 21 new images through the frozen stage, with the
    /// adaptive stage iterating 8 epochs x 5 iterations = 40 mini-batches
    /// of 128 latents. (Latents are computed once and reused across
    /// epochs, exactly as our coordinator does.)
    pub fn paper() -> Self {
        EventSpec { batch: 128, iters: 40, new_images: 21 }
    }

    /// A full NICv2-391 learning event (300 new images, 4 epochs over
    /// 14 mini-batches) — used by the battery planner's coarse scenarios.
    pub fn nicv2_full() -> Self {
        EventSpec { batch: 128, iters: 56, new_images: 300 }
    }
}

/// Adaptive-stage training cycles for one event, retraining `[l, L)`.
pub fn adaptive_event_cycles(
    t: &TargetSpec,
    hw: &HwConfig,
    net: &NetDesc,
    first_adaptive: usize,
    ev: &EventSpec,
) -> f64 {
    let mut per_batch = 0.0;
    for (i, layer) in net.adaptive_layers(first_adaptive).iter().enumerate() {
        per_batch += layer_training_cycles(t, hw, layer, ev.batch, i == 0);
    }
    per_batch * ev.iters as f64
}

/// Frozen-stage INT-8 inference cycles for one event's new images.
pub fn frozen_event_cycles(
    t: &TargetSpec,
    hw: &HwConfig,
    net: &NetDesc,
    first_adaptive: usize,
    ev: &EventSpec,
) -> f64 {
    let frozen_macs: u64 = net.layers[..first_adaptive].iter().map(|l| l.macs()).sum();
    let rate = t.isa.int8_macs_per_cyc_core * hw.cores as f64 * t.parallel_eff(hw.cores);
    ev.new_images as f64 * frozen_macs as f64 / rate
}

/// One full learning event: frozen forwards + adaptive training. Seconds.
pub fn event_seconds(
    t: &TargetSpec,
    hw: &HwConfig,
    net: &NetDesc,
    first_adaptive: usize,
    ev: &EventSpec,
) -> f64 {
    let cycles = adaptive_event_cycles(t, hw, net, first_adaptive, ev)
        + frozen_event_cycles(t, hw, net, first_adaptive, ev);
    t.seconds(cycles)
}

/// Native-engine reference check (the kernels' "executable reference"
/// role, §IV-B): for one (layer, pass, batch, L1) confirm that
///
/// 1. the two independent walks of the solver's tile grid agree — the
///    schedule's materialized tile list (`schedule_layer`) versus the
///    kernels-side block-loop accounting (`tiled_macs` + the div_ceil
///    grid). Both derive from the same `solve_tile` dims, so this
///    catches the two implementations drifting apart (loop bounds,
///    edge-tile handling), NOT an engine that ignores the solver —
///    note the engine blocks M by MR panels + thread split, not by
///    the solver's `tm`;
/// 2. the engine kernel *for that pass* (FW, BW-ERR or BW-GRAD — the
///    actual transposed-view packed path) matches its naive oracle
///    within `tol * reduction_len` on a clamped sample of the layer's
///    geometry (full-size numerics would dwarf the test budget; the
///    pack structure is identical either way).
///
/// Returns the checked MAC count.
pub fn reference_check_layer(
    layer: &LayerDesc,
    pass: Pass,
    batch: usize,
    l1_bytes: usize,
    tol: f32,
) -> Result<u64, String> {
    use crate::kernels as nk;
    use crate::simulator::tiling::solve_tile;

    let sched = schedule_layer(layer, pass, batch, l1_bytes);
    let charged = sched.total_macs();
    let executed = nk::tiled_macs(layer, pass, batch, l1_bytes);
    if charged != executed {
        return Err(format!(
            "MAC accounting diverged for layer {} {pass:?} batch {batch}: \
             model charges {charged}, engine performs {executed}",
            layer.idx
        ));
    }
    let geom = sched.geom;
    let dims = solve_tile(&geom, l1_bytes);
    let grid = geom.m.div_ceil(dims.tm) * geom.n.div_ceil(dims.tn) * geom.k.div_ceil(dims.tk);
    if sched.n_tiles != grid {
        return Err(format!(
            "tile grid diverged for layer {} {pass:?}: schedule {} tiles, \
             engine block loops {grid}",
            layer.idx, sched.n_tiles
        ));
    }

    // numeric check of the pass's actual engine kernel on the layer's
    // (clamped) FORWARD geometry: (mb, kb, nb) are the FW operand dims,
    // and each pass reduces over its own axis
    let fw = super::tiling::matmul_geom(layer, Pass::Fw, batch);
    let (mb, kb, nb) = (fw.m.min(48), fw.k.min(96), fw.n.min(48));
    let pass_id = match pass {
        Pass::Fw => 0u64,
        Pass::BwErr => 1,
        Pass::BwGrad => 2,
    };
    let mut rng = crate::util::rng::Rng::new(
        ((layer.idx as u64) << 32) ^ ((batch as u64) << 8) ^ pass_id,
    );
    let mut gen = |len: usize| -> Vec<f32> { (0..len).map(|_| rng.normal() as f32).collect() };
    let x = gen(mb * kb);
    let w = gen(kb * nb);
    let g = gen(mb * nb);
    let eng = nk::Engine::tiled(l1_bytes);
    let (naive, blocked, red) = match pass {
        Pass::Fw => {
            let mut out = vec![0.0f32; mb * nb];
            eng.matmul_fw_into(&x, &w, mb, kb, nb, &mut out);
            (nk::matmul_fw_naive(&x, &w, mb, kb, nb), out, kb)
        }
        Pass::BwErr => {
            let mut out = vec![0.0f32; mb * kb];
            eng.matmul_bw_err_into(&g, &w, mb, kb, nb, &mut out);
            (nk::matmul_bw_err_naive(&g, &w, mb, kb, nb), out, nb)
        }
        Pass::BwGrad => {
            let mut out = vec![0.0f32; kb * nb];
            eng.matmul_bw_grad_into(&x, &g, mb, kb, nb, &mut out);
            (nk::matmul_bw_grad_naive(&x, &g, mb, kb, nb), out, mb)
        }
    };
    for (i, (a, b)) in naive.iter().zip(&blocked).enumerate() {
        if (a - b).abs() >= tol * red as f32 {
            return Err(format!(
                "engine numerics diverged for layer {} {pass:?} at element {i}: \
                 naive {a} vs blocked {b}",
                layer.idx
            ));
        }
    }
    Ok(charged)
}

/// Average training MAC/cyc over the adaptive stage for one mini-batch —
/// the y-axis of Fig. 9.
pub fn adaptive_macs_per_cyc(
    t: &TargetSpec,
    hw: &HwConfig,
    net: &NetDesc,
    first_adaptive: usize,
    batch: usize,
) -> f64 {
    let mut cycles = 0.0;
    let mut macs = 0u64;
    for (i, layer) in net.adaptive_layers(first_adaptive).iter().enumerate() {
        cycles += layer_training_cycles(t, hw, layer, batch, i == 0);
        let passes = if i == 0 { 2 } else { 3 };
        macs += passes * layer.macs() * batch as u64;
    }
    macs as f64 / cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mobilenet_v1_128;
    use crate::simulator::targets::{stm32l4, vega};

    #[test]
    fn native_engine_agrees_with_cycle_model() {
        // the executable-reference contract: tile-grid accounting stays
        // consistent and per-pass blocked numerics == naive numerics
        let net = mobilenet_v1_128();
        for l in [19usize, 22, 27] {
            for pass in Pass::all() {
                for l1 in [32 * 1024usize, 128 * 1024] {
                    let macs = reference_check_layer(net.layer(l), pass, 8, l1, 1e-3)
                        .unwrap_or_else(|e| panic!("{e}"));
                    assert!(macs > 0);
                }
            }
        }
    }

    #[test]
    fn tiling_overhead_near_paper_7pct() {
        // compare tiled layer time vs pure single-tile compute at the same
        // kernel rate — paper measures ~7% on VEGA
        let v = vega();
        let hw = v.default_hw;
        let net = mobilenet_v1_128();
        let layer = net.layer(22); // the paper's tiling example
        let tiled = layer_pass_cycles(&v, &hw, layer, Pass::Fw, 128);
        let sched = schedule_layer(layer, Pass::Fw, 128, hw.l1_bytes);
        let pure: f64 = sched
            .tiles
            .iter()
            .map(|t_| {
                tile_cycles(&v, hw.cores, layer.kind, Pass::Fw, t_.macs, sched.k_inner, false)
            })
            .sum();
        let overhead = tiled / pure - 1.0;
        assert!(
            (0.0..0.15).contains(&overhead),
            "tiling overhead {overhead} out of range"
        );
    }

    #[test]
    fn vega_vs_stm32_event_latency_anchor() {
        // paper: VEGA ~65x faster than STM32L4 across LR layers
        let v = vega();
        let s = stm32l4();
        let net = mobilenet_v1_128();
        let ev = EventSpec::paper();
        for l in [20usize, 23, 27] {
            let tv = event_seconds(&v, &v.default_hw, &net, l, &ev);
            let ts = event_seconds(&s, &s.default_hw, &net, l, &ev);
            let speedup = ts / tv;
            // paper: 65x on average over the FP32-training-dominated rows;
            // the l=27 row is frozen-INT8-dominated and lands differently
            // (the paper's own Table IV row gives 42x there)
            assert!(
                (30.0..130.0).contains(&speedup),
                "l={l}: speed-up {speedup} out of range"
            );
        }
    }

    #[test]
    fn energy_ratio_anchor() {
        // paper: ~37x more energy-efficient than the STM32L4
        let v = vega();
        let s = stm32l4();
        let net = mobilenet_v1_128();
        let ev = EventSpec::paper();
        let l = 23;
        let ev_j = v.energy_j(event_seconds(&v, &v.default_hw, &net, l, &ev));
        let es_j = s.energy_j(event_seconds(&s, &s.default_hw, &net, l, &ev));
        let ratio = es_j / ev_j;
        assert!((20.0..60.0).contains(&ratio), "energy ratio {ratio}");
    }

    #[test]
    fn deeper_split_is_cheaper() {
        let v = vega();
        let net = mobilenet_v1_128();
        let ev = EventSpec::paper();
        let mut prev = f64::INFINITY;
        for l in [20usize, 22, 24, 26, 27] {
            let t = event_seconds(&v, &v.default_hw, &net, l, &ev);
            assert!(t < prev, "l={l}: {t} not < {prev}");
            prev = t;
        }
    }

    #[test]
    fn frozen_dominated_by_adaptive() {
        // paper §V-D: "frozen stage latencies are utterly dominated by the
        // adaptive stage" (except l=27)
        let v = vega();
        let net = mobilenet_v1_128();
        let ev = EventSpec::paper();
        for l in [20usize, 23] {
            let a = adaptive_event_cycles(&v, &v.default_hw, &net, l, &ev);
            let f = frozen_event_cycles(&v, &v.default_hw, &net, l, &ev);
            assert!(a > 20.0 * f, "l={l}: adaptive {a} vs frozen {f}");
        }
        // l=27: frozen is a visible fraction (~1/3..1/6 of total)
        let a27 = adaptive_event_cycles(&v, &v.default_hw, &net, 27, &ev);
        let f27 = frozen_event_cycles(&v, &v.default_hw, &net, 27, &ev);
        assert!(f27 > 0.1 * a27, "l=27 frozen share too small");
    }

    #[test]
    fn more_bandwidth_never_hurts() {
        let v = vega();
        let net = mobilenet_v1_128();
        let mut prev = 0.0;
        for bw in [8.0, 16.0, 32.0, 64.0, 128.0] {
            let hw = HwConfig {
                dma_read_bits_per_cyc: bw,
                dma_write_bits_per_cyc: bw,
                full_duplex: false,
                ..v.default_hw
            };
            let r = adaptive_macs_per_cyc(&v, &hw, &net, 20, 128);
            assert!(r >= prev - 1e-9, "bw {bw}: {r} < {prev}");
            prev = r;
        }
    }

    #[test]
    fn single_core_is_compute_bound_at_any_bw() {
        // Fig. 9: 1-core MAC/cyc flat across DMA bandwidth
        let v = vega();
        let net = mobilenet_v1_128();
        let at = |bw: f64| {
            let hw = HwConfig {
                cores: 1,
                dma_read_bits_per_cyc: bw,
                dma_write_bits_per_cyc: bw,
                full_duplex: false,
                ..v.default_hw
            };
            adaptive_macs_per_cyc(&v, &hw, &net, 20, 128)
        };
        let lo = at(8.0);
        let hi = at(128.0);
        assert!((hi / lo - 1.0).abs() < 0.08, "1-core spread {} vs {}", lo, hi);
    }

    #[test]
    fn eight_cores_are_dma_bound_at_low_bw() {
        // Fig. 9: 8-core performance collapses at 8 bit/cyc, recovers by 64
        let v = vega();
        let net = mobilenet_v1_128();
        let at = |bw: f64| {
            let hw = HwConfig {
                dma_read_bits_per_cyc: bw,
                dma_write_bits_per_cyc: bw,
                full_duplex: false,
                ..v.default_hw
            };
            adaptive_macs_per_cyc(&v, &hw, &net, 20, 128)
        };
        assert!(at(64.0) / at(8.0) > 1.5, "8-core bw sensitivity too small");
    }
}
