//! Single-tile kernel cycle model — regenerates Fig. 8.
//!
//! The model: one FP32 matmul output element costs `c_mac` cycles per
//! inner-loop (K) iteration plus `c_outer` amortized overhead, split over
//! `cores` with contention efficiency, capped by the shared-FPU ceiling.
//! Depthwise layers use the short-loop `dw_c_mac` coefficient and pay the
//! software-im2col surcharge unless the DMA performs the transform during
//! the L2→L1 transfer (§IV-B). Backward passes apply the transposed-
//! geometry reuse factors (§V-C: −22% BW-ERR, −46% BW-GRAD).

use super::targets::TargetSpec;
use crate::models::LayerKind;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pass {
    Fw,
    BwErr,
    BwGrad,
}

impl Pass {
    pub fn label(&self) -> &'static str {
        match self {
            Pass::Fw => "FW",
            Pass::BwErr => "BW-ERR",
            Pass::BwGrad => "BW-GRAD",
        }
    }

    pub fn all() -> [Pass; 3] {
        [Pass::Fw, Pass::BwErr, Pass::BwGrad]
    }
}

fn pass_factor(t: &TargetSpec, pass: Pass) -> f64 {
    match pass {
        Pass::Fw => 1.0,
        Pass::BwErr => t.isa.bw_err_factor,
        Pass::BwGrad => t.isa.bw_grad_factor,
    }
}

/// Steady-state MAC/cyc of one tile with inner-loop length `k_inner`.
///
/// `dma_im2col`: for DW tiles, whether the cluster DMA performs im2col
/// during the transfer (true on VEGA's tiled path; false for the plain
/// single-tile benchmark of Fig. 8, which is what the paper plots).
pub fn tile_macs_per_cyc(
    t: &TargetSpec,
    cores: usize,
    kind: LayerKind,
    pass: Pass,
    k_inner: usize,
    dma_im2col: bool,
) -> f64 {
    let isa = &t.isa;
    let base = match kind {
        LayerKind::DepthWise => {
            // K = 9 taps; filter-only reuse. im2col surcharge multiplies
            // latency by (1 + ratio) when done in software.
            let cyc_per_mac = isa.dw_c_mac;
            let marshal = if dma_im2col { 1.0 } else { 1.0 + isa.im2col_ratio };
            cores as f64 * t.parallel_eff(cores) / (cyc_per_mac * marshal)
        }
        _ => {
            // PW / Linear / stem conv: long-K matmul
            let cyc_per_mac = isa.c_mac + isa.c_outer / k_inner.max(1) as f64;
            cores as f64 * t.parallel_eff(cores) / cyc_per_mac
        }
    };
    (base * pass_factor(t, pass)).min(isa.fpu_ceiling)
}

/// Inner-loop length the kernel model should amortize `c_outer` over:
/// the L1-resident reduction length `tk` of the *forward* schedule (the
/// paper's inner loop grows with L1), or the 9 taps for depthwise.
/// Backward passes reuse the forward length — their reduced data reuse is
/// captured by the −22%/−46% factors, not by shrinking the loop twice.
pub fn k_inner_for(kind: LayerKind, _pass: Pass, tk: usize, _n: usize, _tm: usize) -> usize {
    match kind {
        LayerKind::DepthWise => 9,
        _ => tk,
    }
}

/// Cycles to execute one tile of `macs` MACs at the tile's steady rate,
/// plus the per-tile prologue.
pub fn tile_cycles(
    t: &TargetSpec,
    cores: usize,
    kind: LayerKind,
    pass: Pass,
    macs: u64,
    k_inner: usize,
    dma_im2col: bool,
) -> f64 {
    let rate = tile_macs_per_cyc(t, cores, kind, pass, k_inner, dma_im2col);
    macs as f64 / rate + t.isa.prologue
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::targets::{stm32l4, vega};

    const PW: LayerKind = LayerKind::PointWise;
    const DW: LayerKind = LayerKind::DepthWise;

    #[test]
    fn fig8_peak_pw_fw_anchor() {
        // paper: PW FW on 8 cores, 512 kB L1 (K=2048) -> 1.91 MAC/cyc
        let v = vega();
        let r = tile_macs_per_cyc(&v, 8, PW, Pass::Fw, 2048, false);
        assert!((r - 1.91).abs() < 0.15, "peak PW FW {r}");
    }

    #[test]
    fn fig8_l1_scaling_anchor() {
        // paper: +11% going from 128 kB (K=512) to 512 kB (K=2048)
        let v = vega();
        let small = tile_macs_per_cyc(&v, 8, PW, Pass::Fw, 512, false);
        let big = tile_macs_per_cyc(&v, 8, PW, Pass::Fw, 2048, false);
        let gain = big / small - 1.0;
        assert!((0.06..0.16).contains(&gain), "L1 gain {gain}");
    }

    #[test]
    fn fig8_backward_factors() {
        let v = vega();
        let fw = tile_macs_per_cyc(&v, 8, PW, Pass::Fw, 512, false);
        let be = tile_macs_per_cyc(&v, 8, PW, Pass::BwErr, 512, false);
        let bg = tile_macs_per_cyc(&v, 8, PW, Pass::BwGrad, 512, false);
        assert!((be / fw - 0.78).abs() < 0.02);
        assert!((bg / fw - 0.54).abs() < 0.02);
    }

    #[test]
    fn dw_is_slower_and_im2col_hurts(){
        let v = vega();
        let pw = tile_macs_per_cyc(&v, 8, PW, Pass::Fw, 512, false);
        let dw_dma = tile_macs_per_cyc(&v, 8, DW, Pass::Fw, 9, true);
        let dw_sw = tile_macs_per_cyc(&v, 8, DW, Pass::Fw, 9, false);
        assert!(dw_dma < pw);
        assert!(dw_sw < dw_dma);
        // paper: "up to 1 MAC/cyc for depthwise forward" with DMA im2col
        assert!((0.8..1.2).contains(&dw_dma), "dw dma {dw_dma}");
        // software im2col costs ~70% extra latency
        assert!((dw_dma / dw_sw - 1.7).abs() < 0.05);
    }

    #[test]
    fn more_cores_always_helps_but_sublinearly() {
        let v = vega();
        let mut prev = 0.0;
        for cores in [1, 2, 4, 8] {
            let r = tile_macs_per_cyc(&v, cores, PW, Pass::Fw, 512, false);
            assert!(r > prev, "cores {cores}: {r} <= {prev}");
            prev = r;
        }
        let r1 = tile_macs_per_cyc(&v, 1, PW, Pass::Fw, 512, false);
        let r8 = tile_macs_per_cyc(&v, 8, PW, Pass::Fw, 512, false);
        assert!(r8 / r1 < 8.0 && r8 / r1 > 6.5, "speedup {}", r8 / r1);
    }

    #[test]
    fn fpu_ceiling_binds_eventually() {
        let v = vega();
        // hypothetical 64-core cluster would hit the 4-FPU ceiling
        let r = tile_macs_per_cyc(&v, 64, PW, Pass::Fw, 4096, false);
        assert!(r <= v.isa.fpu_ceiling + 1e-9);
    }

    #[test]
    fn stm32_much_slower_per_cycle() {
        let v = vega();
        let s = stm32l4();
        let rv = tile_macs_per_cyc(&v, 8, PW, Pass::Fw, 512, false);
        let rs = tile_macs_per_cyc(&s, 1, PW, Pass::Fw, 512, false);
        // cycle-for-cycle ~ 2.25x instr * 7.2x parallel ~ 14-18x
        let ratio = rv / rs;
        assert!((10.0..25.0).contains(&ratio), "cycle ratio {ratio}");
    }

    #[test]
    fn k_inner_geometry() {
        assert_eq!(k_inner_for(PW, Pass::Fw, 512, 256, 64), 512);
        assert_eq!(k_inner_for(PW, Pass::BwErr, 512, 256, 64), 512);
        assert_eq!(k_inner_for(DW, Pass::Fw, 512, 512, 64), 9);
    }

    #[test]
    fn tile_cycles_scale_with_macs() {
        let v = vega();
        let c1 = tile_cycles(&v, 8, PW, Pass::Fw, 1_000_000, 512, false);
        let c2 = tile_cycles(&v, 8, PW, Pass::Fw, 2_000_000, 512, false);
        assert!(c2 > 1.9 * c1 && c2 < 2.1 * c1);
    }
}
