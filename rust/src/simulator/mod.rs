//! Performance-model substrate: the VEGA SoC and STM32L4 baselines.
//!
//! The paper evaluates its CL primitives on silicon we don't have, so this
//! module implements the substitution of DESIGN.md §1: a mechanistic
//! cycle/energy model of the PULP cluster (and the STM32L4 single-core
//! baseline) driven by the same quantities the paper reports — instruction
//! counts per MAC, parallel efficiency, L1 tile geometry, and L2↔L1 DMA
//! bandwidth. Calibration anchors are listed in DESIGN.md §7 and asserted
//! (with tolerance) by the integration tests.
//!
//! Model layering:
//!  - [`targets`]  — per-target ISA/µarch constants (VEGA, STM32L4, SD845)
//!  - [`kernels`]  — single-tile MAC/cyc for {PW, DW, Linear} × {FW,
//!    BW-ERR, BW-GRAD} (regenerates Fig. 8)
//!  - [`tiling`]   — the L1 double-buffer tile solver (§IV-B, Fig. 4)
//!  - [`dma`]      — transfer-time model (regenerates Fig. 9)
//!  - [`executor`] — layer/stage/event roll-ups (Table IV)
//!  - [`energy`]   — power + battery-lifetime model (Fig. 10)

pub mod dma;
pub mod energy;
pub mod executor;
pub mod kernels;
pub mod targets;
pub mod tiling;

pub use executor::{adaptive_event_cycles, frozen_event_cycles, EventSpec};
pub use kernels::{tile_macs_per_cyc, Pass};
pub use targets::{HwConfig, TargetSpec};
