//! Fleet ingress: a bounded multi-producer/multi-consumer event queue
//! built on `std` primitives (`Mutex` + two `Condvar`s — the build is
//! fully offline, so no crossbeam).
//!
//! Two properties the server leans on:
//!
//! - **bounded**: producers block once `capacity` events are in flight,
//!   so a burst of tenants cannot balloon host memory — backpressure
//!   propagates to the caller, matching the paper's fixed-budget ethos;
//! - **batched pops**: [`Bounded::pop_many`] hands a worker up to `max`
//!   queued events in one critical section — the raw material for
//!   cross-tenant frozen-forward coalescing (one engine call per popped
//!   batch, not per event).
//!
//! Two hardening properties on top (the chaos suite leans on these):
//!
//! - **no unbounded waits**: every `Condvar` wait is a `wait_timeout`
//!   tick that re-checks the predicate *and* the shutdown flag, so a
//!   lost wakeup can stall a worker for at most one tick, never forever;
//! - **poison maps to shutdown**: if a producer or worker panicked while
//!   holding the queue mutex, the poisoned lock is recovered
//!   (`into_inner`) and the queue transitions to closed — every other
//!   thread drains and exits cleanly instead of aborting the process on
//!   an `unwrap`.
//!
//! [`Bounded::wait_space`] is the admission-control probe: it waits (up
//! to a deadline) for free capacity WITHOUT enqueueing, so a shedding
//! submitter can bound its worst-case latency and reject instead of
//! blocking forever.
//!
//! Per-tenant event ORDER is not this queue's job: events carry a
//! per-tenant sequence number assigned at submit time, and tenants apply
//! them in sequence (parking early arrivals), so any worker may pop any
//! batch without reordering a tenant's stream.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Wait-loop tick: the upper bound on how long a lost wakeup (or a
/// poison-induced close that raced a wait) can stall a thread.
const TICK: Duration = Duration::from_millis(50);

struct State<T> {
    queue: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC channel. All methods take `&self`; share it by reference
/// across producers and consumers — in production the consumers are
/// pool-resident serving tasks on the shared [`crate::exec::ExecPool`]
/// (one task per configured worker, zero per-run thread spawns), but any
/// thread may produce or consume.
pub struct Bounded<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> Bounded<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "Bounded queue needs capacity >= 1");
        Bounded {
            state: Mutex::new(State { queue: VecDeque::with_capacity(capacity), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Lock the state, mapping a poisoned mutex (some thread panicked
    /// mid-critical-section) to an immediate close: the data may be in
    /// an arbitrary but structurally valid state, so the safe move is to
    /// stop admitting, let workers drain, and exit cleanly.
    fn lock_state(&self) -> MutexGuard<'_, State<T>> {
        match self.state.lock() {
            Ok(st) => st,
            Err(poisoned) => {
                let mut st = poisoned.into_inner();
                st.closed = true;
                st
            }
        }
    }

    /// One timed wait tick on `cv`, with the same poison policy.
    fn wait_tick<'a>(
        &self,
        cv: &Condvar,
        st: MutexGuard<'a, State<T>>,
        dur: Duration,
    ) -> MutexGuard<'a, State<T>> {
        match cv.wait_timeout(st, dur) {
            Ok((st, _timeout)) => st,
            Err(poisoned) => {
                let (mut st, _timeout) = poisoned.into_inner();
                st.closed = true;
                st
            }
        }
    }

    /// Enqueue, blocking while the queue is full. Returns `false` (and
    /// drops `item`) if the queue has been closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.lock_state();
        while st.queue.len() >= self.capacity && !st.closed {
            st = self.wait_tick(&self.not_full, st, TICK);
        }
        if st.closed {
            return false;
        }
        st.queue.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        true
    }

    /// Wait up to `timeout` for free capacity WITHOUT enqueueing: the
    /// admission-control probe. Returns `true` when a push would not
    /// block right now (free slot, or closed — a closed queue fails the
    /// push instantly, which also doesn't block), `false` on timeout.
    /// Advisory by nature: another producer may take the slot first, in
    /// which case the subsequent `push` blocks briefly — the bound this
    /// buys is "not stuck behind a full queue for the whole timeout".
    pub fn wait_space(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut st = self.lock_state();
        loop {
            if st.closed || st.queue.len() < self.capacity {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            st = self.wait_tick(&self.not_full, st, (deadline - now).min(TICK));
        }
    }

    /// Dequeue up to `max` items, blocking while the queue is empty.
    /// Returns an empty vec only when the queue is closed AND drained —
    /// the workers' shutdown signal.
    pub fn pop_many(&self, max: usize) -> Vec<T> {
        self.pop_many_observed(max).0
    }

    /// Like [`Bounded::pop_many`], but also reports the queue depth
    /// observed at pop time (taken batch + events left behind) — the
    /// ingress-depth telemetry gauge, read in the same critical section
    /// so the figure is coherent with the batch.
    pub fn pop_many_observed(&self, max: usize) -> (Vec<T>, usize) {
        let max = max.max(1);
        let mut st = self.lock_state();
        while st.queue.is_empty() && !st.closed {
            st = self.wait_tick(&self.not_empty, st, TICK);
        }
        let depth = st.queue.len();
        let take = depth.min(max);
        let out: Vec<T> = st.queue.drain(..take).collect();
        drop(st);
        if !out.is_empty() {
            // waking all parked producers is correct and simple; they
            // re-check the capacity predicate under the lock
            self.not_full.notify_all();
            // more items may remain for other workers
            self.not_empty.notify_one();
        }
        (out, depth)
    }

    /// Dequeue one item (blocking); `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        self.pop_many(1).into_iter().next()
    }

    /// Close the queue: producers fail fast, workers drain then exit.
    pub fn close(&self) {
        self.lock_state().closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.lock_state().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fifo_single_thread() {
        let q = Bounded::new(8);
        for i in 0..5 {
            assert!(q.push(i));
        }
        assert_eq!(q.len(), 5);
        assert_eq!(q.pop_many(3), vec![0, 1, 2]);
        assert_eq!(q.pop(), Some(3));
        q.close();
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None, "closed + drained");
        assert!(!q.push(9), "push after close fails");
    }

    #[test]
    fn bounded_blocks_producer_until_consumed() {
        let q = Bounded::new(2);
        let produced = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..20 {
                    q.push(i);
                    produced.fetch_add(1, Ordering::SeqCst);
                }
                q.close();
            });
            let mut got = Vec::new();
            loop {
                let batch = q.pop_many(4);
                if batch.is_empty() {
                    break;
                }
                // capacity bound: the producer can never run more than
                // queue capacity ahead of what we've consumed
                assert!(produced.load(Ordering::SeqCst) <= got.len() + batch.len() + 2);
                got.extend(batch);
            }
            assert_eq!(got, (0..20).collect::<Vec<_>>());
        });
    }

    #[test]
    fn multi_worker_drain_is_a_partition() {
        let q = Bounded::new(16);
        let seen = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| loop {
                    let batch = q.pop_many(4);
                    if batch.is_empty() {
                        break;
                    }
                    seen.lock().unwrap().extend(batch);
                });
            }
            for i in 0..200 {
                q.push(i);
            }
            q.close();
        });
        let mut all = seen.into_inner().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..200).collect::<Vec<_>>());
    }

    #[test]
    fn wait_space_reports_capacity_and_times_out_when_full() {
        let q = Bounded::new(2);
        assert!(q.wait_space(Duration::ZERO), "empty queue has space instantly");
        q.push(1);
        q.push(2);
        let t0 = Instant::now();
        assert!(!q.wait_space(Duration::from_millis(20)), "full queue must time out");
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert_eq!(q.pop(), Some(1));
        assert!(q.wait_space(Duration::ZERO), "a pop frees a slot");
        q.close();
        assert!(q.wait_space(Duration::ZERO), "closed never blocks a push (it fails fast)");
    }

    #[test]
    fn wait_space_wakes_when_a_consumer_frees_a_slot() {
        let q = Bounded::new(1);
        q.push(7);
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(10));
                assert_eq!(q.pop(), Some(7));
            });
            // well under the tick: the wakeup (not the timeout tick)
            // must deliver the slot
            assert!(q.wait_space(Duration::from_secs(5)));
        });
    }

    #[test]
    fn poisoned_queue_drains_cleanly_instead_of_aborting() {
        let q: Bounded<i32> = Bounded::new(4);
        q.push(1);
        q.push(2);
        // poison the mutex: a panic while holding the guard
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = q.state.lock().unwrap();
            panic!("injected panic while holding the ingress lock");
        }));
        assert!(result.is_err());
        // every path now sees a closed queue and exits cleanly: workers
        // drain what's left, producers fail fast, nothing unwraps
        assert_eq!(q.pop_many(8), vec![1, 2]);
        assert_eq!(q.pop(), None, "closed + drained after poison");
        assert!(!q.push(3), "push after poison-close fails fast");
        assert!(q.wait_space(Duration::ZERO));
    }
}
