//! Fleet ingress: a bounded multi-producer/multi-consumer event queue
//! built on `std` primitives (`Mutex` + two `Condvar`s — the build is
//! fully offline, so no crossbeam).
//!
//! Two properties the server leans on:
//!
//! - **bounded**: producers block once `capacity` events are in flight,
//!   so a burst of tenants cannot balloon host memory — backpressure
//!   propagates to the caller, matching the paper's fixed-budget ethos;
//! - **batched pops**: [`Bounded::pop_many`] hands a worker up to `max`
//!   queued events in one critical section — the raw material for
//!   cross-tenant frozen-forward coalescing (one engine call per popped
//!   batch, not per event).
//!
//! Per-tenant event ORDER is not this queue's job: events carry a
//! per-tenant sequence number assigned at submit time, and tenants apply
//! them in sequence (parking early arrivals), so any worker may pop any
//! batch without reordering a tenant's stream.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct State<T> {
    queue: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC channel. All methods take `&self`; share it by reference
/// across scoped producer/worker threads.
pub struct Bounded<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl<T> Bounded<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "Bounded queue needs capacity >= 1");
        Bounded {
            state: Mutex::new(State { queue: VecDeque::with_capacity(capacity), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue, blocking while the queue is full. Returns `false` (and
    /// drops `item`) if the queue has been closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.queue.len() >= self.capacity && !st.closed {
            st = self.not_full.wait(st).unwrap();
        }
        if st.closed {
            return false;
        }
        st.queue.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        true
    }

    /// Dequeue up to `max` items, blocking while the queue is empty.
    /// Returns an empty vec only when the queue is closed AND drained —
    /// the workers' shutdown signal.
    pub fn pop_many(&self, max: usize) -> Vec<T> {
        let max = max.max(1);
        let mut st = self.state.lock().unwrap();
        while st.queue.is_empty() && !st.closed {
            st = self.not_empty.wait(st).unwrap();
        }
        let take = st.queue.len().min(max);
        let out: Vec<T> = st.queue.drain(..take).collect();
        drop(st);
        if !out.is_empty() {
            // waking all parked producers is correct and simple; they
            // re-check the capacity predicate under the lock
            self.not_full.notify_all();
            // more items may remain for other workers
            self.not_empty.notify_one();
        }
        out
    }

    /// Dequeue one item (blocking); `None` once closed and drained.
    pub fn pop(&self) -> Option<T> {
        self.pop_many(1).into_iter().next()
    }

    /// Close the queue: producers fail fast, workers drain then exit.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fifo_single_thread() {
        let q = Bounded::new(8);
        for i in 0..5 {
            assert!(q.push(i));
        }
        assert_eq!(q.len(), 5);
        assert_eq!(q.pop_many(3), vec![0, 1, 2]);
        assert_eq!(q.pop(), Some(3));
        q.close();
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None, "closed + drained");
        assert!(!q.push(9), "push after close fails");
    }

    #[test]
    fn bounded_blocks_producer_until_consumed() {
        let q = Bounded::new(2);
        let produced = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..20 {
                    q.push(i);
                    produced.fetch_add(1, Ordering::SeqCst);
                }
                q.close();
            });
            let mut got = Vec::new();
            loop {
                let batch = q.pop_many(4);
                if batch.is_empty() {
                    break;
                }
                // capacity bound: the producer can never run more than
                // queue capacity ahead of what we've consumed
                assert!(produced.load(Ordering::SeqCst) <= got.len() + batch.len() + 2);
                got.extend(batch);
            }
            assert_eq!(got, (0..20).collect::<Vec<_>>());
        });
    }

    #[test]
    fn multi_worker_drain_is_a_partition() {
        let q = Bounded::new(16);
        let seen = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| loop {
                    let batch = q.pop_many(4);
                    if batch.is_empty() {
                        break;
                    }
                    seen.lock().unwrap().extend(batch);
                });
            }
            for i in 0..200 {
                q.push(i);
            }
            q.close();
        });
        let mut all = seen.into_inner().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..200).collect::<Vec<_>>());
    }
}
