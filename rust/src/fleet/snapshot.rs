//! Versioned, checksummed binary snapshots of full tenant state — the
//! cold tier of the fleet's replay-memory hierarchy.
//!
//! A spilled tenant is exactly a [`TenantSnapshot`] on disk: adaptive
//! head, packed replay arena + quantization parameters, RNG stream
//! position, metrics, and the next event sequence number. The format is
//! deliberately dumb — fixed little-endian scalars behind a magic,
//! version, and FNV-1a checksum header — so a spill→restore cycle is
//! **bit-exact** (every f32 round-trips through its raw bits) and a
//! corrupted, truncated, or future-versioned file is rejected with a
//! clean error before any state is rebuilt. Structural invariants
//! (arena length, filled-slot/label consistency, slot byte alignment)
//! are re-validated on decode via `ReplayBuffer::from_*_parts`, so even
//! a file that passes the checksum cannot smuggle in a corrupt buffer.
//!
//! Layout:
//!
//! ```text
//! [0..4)   magic  b"TCSN"
//! [4..8)   version u32 (currently 1)
//! [8..16)  payload length u64
//! [16..24) FNV-1a 64 checksum of the payload
//! [24..)   payload (config, seq, metrics, rng, params, replay)
//! ```

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::replay::ReplayBuffer;
use crate::coordinator::trainer::CLConfig;
use crate::fleet::tenant::{TenantMetrics, TenantSnapshot};
use crate::net::wire::{fnv1a64, Reader, Writer};
use crate::runtime::{ParamState, TensorF32};
use crate::util::rng::Rng;

/// File magic: "TinyCl SNapshot".
pub const SNAPSHOT_MAGIC: [u8; 4] = *b"TCSN";

/// Current format version. Bump on any layout change; old readers must
/// reject newer files rather than misparse them.
pub const SNAPSHOT_VERSION: u32 = 1;

const HEADER_LEN: usize = 24;

// ---- encode ----------------------------------------------------------------
// Scalar encoding is the shared `net::wire` codec; this module owns only
// the field order, the header, and the structural validation. The byte
// format is pinned by the round-trip tests below and by the golden
// fixture in `tools/fixtures/` — migration frames carry these bytes
// across hosts, so any layout change must bump SNAPSHOT_VERSION.

/// Serialize a tenant snapshot to the versioned, checksummed byte form.
pub fn encode(snap: &TenantSnapshot) -> Vec<u8> {
    let mut w = Writer::new();
    // config
    w.u32(snap.cfg.l as u32);
    w.u64(snap.cfg.n_lr as u64);
    w.u8(snap.cfg.lr_bits);
    w.u8(snap.cfg.int8_frozen as u8);
    w.f32(snap.cfg.lr);
    w.u64(snap.cfg.epochs as u64);
    w.u64(snap.cfg.seed);
    // sequence position
    w.u64(snap.next_seq);
    // metrics
    w.u64(snap.metrics.events);
    w.u64(snap.metrics.steps);
    w.u64(snap.metrics.train_seen);
    w.u64(snap.metrics.train_correct);
    w.f64(snap.metrics.last_loss);
    w.u32(snap.metrics.demotions);
    w.u32(snap.metrics.shrinks);
    w.u32(snap.metrics.promotions);
    w.u32(snap.metrics.spills);
    // rng stream position
    for word in snap.rng.state() {
        w.u64(word);
    }
    // adaptive params
    w.u32(snap.params.len() as u32);
    for (name, t) in snap.params.names().iter().zip(snap.params.tensors()) {
        w.str(name);
        w.u8(t.shape.len() as u8);
        for &d in &t.shape {
            w.u32(d as u32);
        }
        w.u64(t.data.len() as u64);
        for &v in &t.data {
            w.f32(v);
        }
    }
    // replay memory
    w.u64(snap.replay.capacity() as u64);
    w.u64(snap.replay.latent_elems() as u64);
    if let Some((arena, bits, a_max)) = snap.replay.packed_parts() {
        w.u8(0); // packed mode
        w.u8(bits);
        w.f32(a_max);
        w.u64(arena.len() as u64);
        w.bytes(arena);
    } else {
        let arena = snap.replay.f32_arena().expect("replay is packed or f32");
        w.u8(1); // f32 mode
        w.u64(arena.len() as u64);
        for &v in arena {
            w.f32(v);
        }
    }
    for &l in snap.replay.labels_raw() {
        w.i32(l);
    }
    w.u64(snap.replay.filled_slots_raw().len() as u64);
    for &s in snap.replay.filled_slots_raw() {
        w.u32(s);
    }
    // parked (sequence-reorder) events: a tenant spilled mid-reorder
    // carries its early arrivals along, so lazy restore resumes parking
    // exactly where it left off
    w.u64(snap.parked.len() as u64);
    for (seq, lat, lab) in &snap.parked {
        w.u64(*seq);
        w.u64(lab.len() as u64);
        for &l in lab {
            w.i32(l);
        }
        for &v in lat {
            w.f32(v);
        }
    }

    let payload = w.into_vec();
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&SNAPSHOT_MAGIC);
    out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

// ---- decode ----------------------------------------------------------------

/// Deserialize a tenant snapshot, verifying magic, version, length and
/// checksum before touching the payload, and re-validating every
/// structural invariant while rebuilding the state.
pub fn decode(bytes: &[u8]) -> Result<TenantSnapshot> {
    ensure!(
        bytes.len() >= HEADER_LEN,
        "truncated snapshot: {} bytes is shorter than the {HEADER_LEN}-byte header",
        bytes.len()
    );
    ensure!(
        bytes[..4] == SNAPSHOT_MAGIC,
        "not a tinycl tenant snapshot (bad magic {:02x?})",
        &bytes[..4]
    );
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    ensure!(
        version == SNAPSHOT_VERSION,
        "unsupported snapshot version {version} (this build reads version {SNAPSHOT_VERSION})"
    );
    let payload_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    ensure!(
        bytes.len() - HEADER_LEN == payload_len,
        "truncated snapshot: header promises {payload_len} payload bytes, file has {}",
        bytes.len() - HEADER_LEN
    );
    let checksum = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let payload = &bytes[HEADER_LEN..];
    ensure!(
        fnv1a64(payload) == checksum,
        "snapshot checksum mismatch (corrupted file)"
    );

    let mut r = Reader::new(payload);
    let cfg = CLConfig {
        l: r.u32()? as usize,
        n_lr: r.u64()? as usize,
        lr_bits: r.u8()?,
        int8_frozen: r.u8()? != 0,
        lr: r.f32()?,
        epochs: r.u64()? as usize,
        seed: r.u64()?,
    };
    let next_seq = r.u64()?;
    let metrics = TenantMetrics {
        events: r.u64()?,
        steps: r.u64()?,
        train_seen: r.u64()?,
        train_correct: r.u64()?,
        last_loss: r.f64()?,
        demotions: r.u32()?,
        shrinks: r.u32()?,
        promotions: r.u32()?,
        spills: r.u32()?,
    };
    let rng_state = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
    ensure!(
        rng_state.iter().any(|&w| w != 0),
        "snapshot RNG state is all-zero (corrupted file)"
    );
    let rng = Rng::from_state(rng_state);

    let n_tensors = r.u32()? as usize;
    ensure!(n_tensors <= 1024, "snapshot tensor count {n_tensors} implausible");
    let mut names = Vec::with_capacity(n_tensors);
    let mut tensors = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        names.push(r.str()?);
        let ndim = r.u8()? as usize;
        ensure!(ndim <= 8, "snapshot tensor rank {ndim} implausible");
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        let n = r.len_bounded(4)?;
        ensure!(
            n == shape.iter().product::<usize>(),
            "snapshot tensor data length {n} does not match shape {shape:?}"
        );
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(r.f32()?);
        }
        tensors.push(TensorF32::new(shape, data));
    }
    let params = ParamState::from_tensors(names, tensors);

    let capacity = r.u64()? as usize;
    let latent_elems = r.u64()? as usize;
    // labels alone take 4 B/slot, so any capacity beyond payload/4 is
    // corruption — reject before Vec::with_capacity can over-allocate
    ensure!(
        capacity.saturating_mul(4) <= payload.len() && latent_elems <= payload.len(),
        "snapshot replay geometry exceeds the payload ({capacity} slots x {latent_elems} elems)"
    );
    let mode = r.u8()?;
    let replay = match mode {
        0 => {
            let bits = r.u8()?;
            let a_max = r.f32()?;
            let n = r.len_bounded(1)?;
            let arena = r.take(n)?.to_vec();
            let mut labels = Vec::with_capacity(capacity);
            for _ in 0..capacity {
                labels.push(r.i32()?);
            }
            let n_filled = r.len_bounded(4)?;
            let mut filled = Vec::with_capacity(n_filled);
            for _ in 0..n_filled {
                filled.push(r.u32()?);
            }
            ReplayBuffer::from_packed_parts(
                capacity,
                latent_elems,
                bits,
                a_max,
                arena,
                labels,
                filled,
            )?
        }
        1 => {
            let n = r.len_bounded(4)?;
            let mut arena = Vec::with_capacity(n);
            for _ in 0..n {
                arena.push(r.f32()?);
            }
            let mut labels = Vec::with_capacity(capacity);
            for _ in 0..capacity {
                labels.push(r.i32()?);
            }
            let n_filled = r.len_bounded(4)?;
            let mut filled = Vec::with_capacity(n_filled);
            for _ in 0..n_filled {
                filled.push(r.u32()?);
            }
            ReplayBuffer::from_f32_parts(capacity, latent_elems, arena, labels, filled)?
        }
        other => bail!("snapshot replay mode {other} unknown (corrupted file)"),
    };
    let n_parked = r.len_bounded(16)?;
    let mut parked = Vec::with_capacity(n_parked);
    let mut prev_seq = None;
    for _ in 0..n_parked {
        let seq = r.u64()?;
        ensure!(
            seq >= next_seq && prev_seq.is_none_or(|p| seq > p),
            "snapshot parked events out of order (corrupted file)"
        );
        prev_seq = Some(seq);
        let n = r.len_bounded(4)?;
        ensure!(n >= 1, "snapshot parked event {seq} is empty");
        let mut lab = Vec::with_capacity(n);
        for _ in 0..n {
            lab.push(r.i32()?);
        }
        let n_lat = n
            .checked_mul(latent_elems)
            .filter(|&b| b.checked_mul(4).is_some_and(|x| x <= payload.len()))
            .ok_or_else(|| anyhow::anyhow!("snapshot parked event {seq} latents implausible"))?;
        let mut lat = Vec::with_capacity(n_lat);
        for _ in 0..n_lat {
            lat.push(r.f32()?);
        }
        parked.push((seq, lat, lab));
    }
    ensure!(
        r.pos() == payload.len(),
        "snapshot has {} trailing bytes",
        payload.len() - r.pos()
    );

    Ok(TenantSnapshot { cfg, params, replay, rng, metrics, next_seq, parked })
}

// ---- file helpers ----------------------------------------------------------

/// Write a snapshot to `path` durably. Returns the encoded size in
/// bytes — the disk charge the governor records for the spill.
pub fn write_file(path: &Path, snap: &TenantSnapshot) -> Result<usize> {
    let bytes = encode(snap);
    write_bytes(path, &bytes)?;
    Ok(bytes.len())
}

/// Publish raw snapshot bytes at `path` via write-tmp + fsync + atomic
/// rename: the data reaches stable storage *before* the rename makes it
/// visible, so a crash (or injected torn write) at any instant leaves
/// either the old published file or the new one — never a half-written
/// snapshot where the restore path will find it. A stale `.tmp` sibling
/// from a previous torn attempt is simply overwritten.
pub fn write_bytes(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating tenant snapshot tmp {}", tmp.display()))?;
        f.write_all(bytes)
            .with_context(|| format!("writing tenant snapshot {}", tmp.display()))?;
        f.sync_all()
            .with_context(|| format!("fsyncing tenant snapshot {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing tenant snapshot {}", path.display()))?;
    // best-effort directory fsync so the rename itself is durable; not
    // all platforms allow opening a directory for sync — ignore errors
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all().ok();
        }
    }
    Ok(())
}

/// Read and decode a snapshot from `path`.
pub fn read_file(path: &Path) -> Result<TenantSnapshot> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading tenant snapshot {}", path.display()))?;
    decode(&bytes).with_context(|| format!("decoding tenant snapshot {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot(bits: u8) -> TenantSnapshot {
        let elems = 16;
        let mut rng = Rng::new(5);
        let mut replay = if bits == 32 {
            ReplayBuffer::new_f32(6, elems)
        } else {
            ReplayBuffer::new_packed(6, elems, bits, 1.25)
        };
        let latents: Vec<f32> = (0..4 * elems).map(|i| (i % 23) as f32 * 0.05).collect();
        let labels: Vec<i32> = (0..4).collect();
        replay.init_fill(&latents, &labels, &mut rng);
        let params = ParamState::from_tensors(
            vec!["layer0.b".into(), "layer0.w".into()],
            vec![
                TensorF32::new(vec![3], vec![0.5, -1.25, 3.75]),
                TensorF32::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
            ],
        );
        TenantSnapshot {
            cfg: CLConfig {
                l: 15,
                n_lr: 6,
                lr_bits: if bits == 32 { 32 } else { bits },
                int8_frozen: true,
                lr: 0.1,
                epochs: 2,
                seed: 42,
            },
            params,
            replay,
            rng,
            metrics: TenantMetrics {
                events: 7,
                steps: 21,
                train_seen: 1344,
                train_correct: 900,
                last_loss: 0.75,
                demotions: 1,
                shrinks: 0,
                promotions: 2,
                spills: 3,
            },
            next_seq: 7,
            // spilled mid-reorder: two early arrivals ride along
            parked: vec![
                (8, vec![0.25f32; 2 * 16], vec![3, 4]),
                (10, vec![0.75f32; 16], vec![5]),
            ],
        }
    }

    #[test]
    fn encode_decode_round_trip_is_bit_exact() {
        for bits in [7u8, 8, 32] {
            let snap = sample_snapshot(bits);
            let bytes = encode(&snap);
            let back = decode(&bytes).unwrap();
            // re-encoding the decoded snapshot must reproduce the very
            // same bytes — full bit-exactness across every field
            assert_eq!(encode(&back), bytes, "Q={bits}");
            assert_eq!(back.next_seq, snap.next_seq);
            assert_eq!(back.metrics.promotions, 2);
            assert_eq!(back.rng.state(), snap.rng.state());
        }
    }

    #[test]
    fn corrupted_byte_is_rejected() {
        let bytes = encode(&sample_snapshot(8));
        for flip_at in [HEADER_LEN, HEADER_LEN + 17, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[flip_at] ^= 0x40;
            let err = decode(&bad).unwrap_err().to_string();
            assert!(err.contains("checksum"), "flip at {flip_at}: {err}");
        }
    }

    #[test]
    fn truncation_is_rejected() {
        let bytes = encode(&sample_snapshot(7));
        for keep in [0, 3, HEADER_LEN - 1, HEADER_LEN + 5, bytes.len() - 1] {
            let err = decode(&bytes[..keep]).unwrap_err().to_string();
            assert!(err.contains("truncated"), "keep {keep}: {err}");
        }
    }

    #[test]
    fn wrong_magic_and_version_rejected() {
        let bytes = encode(&sample_snapshot(8));
        let mut bad_magic = bytes.clone();
        bad_magic[0] = b'X';
        assert!(decode(&bad_magic).unwrap_err().to_string().contains("bad magic"));
        let mut bad_version = bytes.clone();
        bad_version[4..8].copy_from_slice(&2u32.to_le_bytes());
        assert!(
            decode(&bad_version)
                .unwrap_err()
                .to_string()
                .contains("unsupported snapshot version 2")
        );
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join(format!("tinycl_snap_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tenant_0.tcsn");
        let snap = sample_snapshot(7);
        let n = write_file(&path, &snap).unwrap();
        assert_eq!(n, std::fs::metadata(&path).unwrap().len() as usize);
        let back = read_file(&path).unwrap();
        assert_eq!(encode(&back), encode(&snap));
        std::fs::remove_dir_all(&dir).ok();
    }
}
