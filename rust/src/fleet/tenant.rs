//! One continual-learning tenant: the per-user slice of fleet state.
//!
//! A tenant owns exactly what the paper says must be private to a
//! learner — the adaptive-stage parameters, the quantized latent-replay
//! memory, and a deterministic RNG stream — and nothing more. The frozen
//! backbone, PTQ calibration and kernel engine live once per host in the
//! shared backend (`Arc`), which is what makes dense multi-tenancy fit
//! the paper's 64 MB envelope.
//!
//! **Single-session parity is structural**: construction and event
//! processing consume the same RNG stream in the same order as
//! [`Session`](crate::coordinator::Session) (same seed derivation, same
//! `fork` tags, same shared [`train_event_on_latents`] /
//! [`eval_on_latents`] loops), so a fleet of one tenant reproduces
//! `run_protocol` bit-for-bit — the N=1 conformance test in
//! `rust/tests/fleet.rs` pins this.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::coordinator::batcher::Batcher;
use crate::coordinator::replay::ReplayBuffer;
use crate::coordinator::trainer::{eval_on_latents, train_event_on_latents, CLConfig, EventStats};
use crate::runtime::{Backend, ParamState};
use crate::util::rng::Rng;

/// Fleet-wide tenant identifier (a slot index in the server).
pub type TenantId = usize;

/// Per-tenant deployment knobs (the fleet-level split/frozen-mode are
/// server-wide — one shared backbone implies one split).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TenantConfig {
    /// replay-memory capacity N_LR
    pub n_lr: usize,
    /// LR storage bits: 6..8 packed, or 32 for the FP32 baseline arm
    pub lr_bits: u8,
    /// SGD learning rate
    pub lr: f32,
    /// epochs over each event's images
    pub epochs: usize,
    /// RNG seed (sampling, replacement, shuffling) — per tenant
    pub seed: u64,
}

impl Default for TenantConfig {
    fn default() -> Self {
        let c = CLConfig::default();
        TenantConfig { n_lr: c.n_lr, lr_bits: c.lr_bits, lr: c.lr, epochs: c.epochs, seed: c.seed }
    }
}

impl TenantConfig {
    /// The equivalent single-session config at the fleet's split/mode.
    pub fn as_cl_config(&self, l: usize, int8_frozen: bool) -> CLConfig {
        CLConfig {
            l,
            n_lr: self.n_lr,
            lr_bits: self.lr_bits,
            int8_frozen,
            lr: self.lr,
            epochs: self.epochs,
            seed: self.seed,
        }
    }
}

/// Training-side bookkeeping the server surfaces per tenant.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantMetrics {
    pub events: u64,
    pub steps: u64,
    pub train_seen: u64,
    pub train_correct: u64,
    pub last_loss: f64,
    pub demotions: u32,
    pub shrinks: u32,
    /// 7→8-bit replay re-widenings (governor pressure cleared)
    pub promotions: u32,
    /// times this tenant's state was spilled to the cold (disk) tier
    pub spills: u32,
}

impl TenantMetrics {
    pub fn train_acc(&self) -> f64 {
        if self.train_seen == 0 {
            0.0
        } else {
            self.train_correct as f64 / self.train_seen as f64
        }
    }
}

pub struct Tenant {
    pub id: TenantId,
    pub cfg: CLConfig,
    pub params: ParamState,
    pub replay: ReplayBuffer,
    batcher: Batcher,
    rng: Rng,
    pub metrics: TenantMetrics,
    /// next event sequence number this tenant will apply
    next_seq: u64,
    /// early arrivals: stage-A-finished events waiting on a predecessor
    /// (latents, labels, submit stamp for latency accounting)
    parked: BTreeMap<u64, (Vec<f32>, Vec<i32>, Option<Instant>)>,
    /// reusable eval staging buffers
    eval_chunk: Vec<f32>,
    logits_chunk: Vec<f32>,
    batch_eval: usize,
}

impl Tenant {
    /// Build a tenant and seed its replay memory from pre-deployment
    /// latents (already through the shared frozen stage). RNG discipline
    /// matches `Session::new`: master stream from
    /// `seed ^ manifest.seed * 0x9E37`, one `fork(0x1417)` for the
    /// initial fill.
    pub fn new(
        id: TenantId,
        be: &dyn Backend,
        l: usize,
        int8_frozen: bool,
        tcfg: TenantConfig,
        init_latents: &[f32],
        init_labels: &[i32],
    ) -> Result<Tenant> {
        let m = be.manifest();
        let cfg = tcfg.as_cl_config(l, int8_frozen);
        let lat = m.latent_info(l)?;
        let latent_elems = lat.elems();
        let a_max = lat.a_max(cfg.int8_frozen);
        let params = be.load_params(l)?;
        let mut replay = if cfg.lr_bits == 32 {
            ReplayBuffer::new_f32(cfg.n_lr, latent_elems)
        } else {
            ReplayBuffer::new_packed(cfg.n_lr, latent_elems, cfg.lr_bits, a_max)
        };
        ensure!(
            init_labels.len() * latent_elems == init_latents.len(),
            "tenant {id}: ragged init latents"
        );
        ensure!(!init_labels.is_empty(), "tenant {id}: empty init set");
        let mut rng = Rng::new(cfg.seed ^ m.seed.wrapping_mul(0x9E37));
        let mut seed_rng = rng.fork(0x1417);
        replay.init_fill(init_latents, init_labels, &mut seed_rng);
        Ok(Tenant {
            id,
            cfg,
            params,
            replay,
            batcher: Batcher::new(m.batch_train, m.batch_new, latent_elems),
            rng,
            metrics: TenantMetrics::default(),
            next_seq: 0,
            parked: BTreeMap::new(),
            eval_chunk: vec![0.0; m.batch_eval * latent_elems],
            logits_chunk: vec![0.0; m.batch_eval * m.num_classes],
            batch_eval: m.batch_eval,
        })
    }

    /// Apply one event's training NOW (latents already computed). Same
    /// loop + RNG order as `Session::run_event`.
    fn process(&mut self, be: &dyn Backend, latents: &[f32], labels: &[i32]) -> Result<EventStats> {
        if self.replay.is_empty() {
            // only a degrade-rebuilt tenant can get here (admission
            // requires a non-empty init set): re-seed the emptied replay
            // memory from the first live event so the trainer's replay
            // sampling has something to draw (the degraded trajectory is
            // already divergent, so the extra master-stream draw the
            // fork consumes costs nothing).
            let mut seed_rng = self.rng.fork(0xDE64);
            self.replay.init_fill(latents, labels, &mut seed_rng);
        }
        self.metrics.events += 1;
        let stats = train_event_on_latents(
            be,
            &self.cfg,
            &mut self.params,
            &mut self.replay,
            &mut self.batcher,
            &mut self.rng,
            self.metrics.events as usize,
            latents,
            labels,
        )?;
        self.metrics.steps += stats.steps as u64;
        let seen = (stats.steps * self.batcher.batch) as u64;
        self.metrics.train_seen += seen;
        self.metrics.train_correct += (stats.train_acc * seen as f64).round() as u64;
        self.metrics.last_loss = stats.mean_loss;
        Ok(stats)
    }

    /// Deliver event `seq` (stage-A latents). Events apply strictly in
    /// sequence regardless of which worker finishes frozen-forward first:
    /// an early arrival parks, and each applied event drains any
    /// now-ready successors. Returns the submit stamps of the events
    /// applied by this call (parked events keep their own stamps, so
    /// latency accounting charges them the waiting they actually did).
    pub fn accept(
        &mut self,
        be: &dyn Backend,
        seq: u64,
        latents: Vec<f32>,
        labels: Vec<i32>,
        submitted: Option<Instant>,
    ) -> Result<Vec<Option<Instant>>> {
        ensure!(
            seq >= self.next_seq && !self.parked.contains_key(&seq),
            "tenant {}: duplicate event seq {seq}",
            self.id
        );
        self.parked.insert(seq, (latents, labels, submitted));
        let mut applied = Vec::new();
        while let Some((lat, lab, stamp)) = self.parked.remove(&self.next_seq) {
            // serve-path span: one in-sequence event applied (wraps the
            // replay-train steps process() runs); inert unless a run has
            // telemetry installed process-globally
            let _sp = crate::telemetry::global()
                .owned_span(crate::telemetry::EventKind::TenantApply)
                .key(self.next_seq)
                .tenant(self.id as u32)
                .payload(lab.len() as u64, 0)
                .hist(crate::telemetry::Path::Serve);
            self.process(be, &lat, &lab)?;
            self.next_seq += 1;
            applied.push(stamp);
        }
        Ok(applied)
    }

    /// Events parked waiting on a predecessor (0 when quiesced).
    pub fn parked_len(&self) -> usize {
        self.parked.len()
    }

    /// Discard parked events (the failed-run recovery path: their
    /// predecessors were dropped with the queue, so they can never
    /// apply). Returns how many were discarded.
    pub fn drop_parked(&mut self) -> usize {
        let n = self.parked.len();
        self.parked.clear();
        n
    }

    /// Sequence number the tenant will apply next — equals the number of
    /// events processed so far.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Top-1 accuracy over precomputed test latents (shared across the
    /// fleet — the frozen stage is identical for every tenant).
    pub fn evaluate(&mut self, be: &dyn Backend, latents: &[f32], labels: &[i32]) -> Result<f64> {
        eval_on_latents(
            be,
            self.cfg.l,
            &self.params,
            latents,
            labels,
            self.batch_eval,
            &mut self.eval_chunk,
            &mut self.logits_chunk,
        )
    }

    /// Live bytes this tenant's replay memory occupies (the governor's
    /// dominant, elastic component).
    pub fn replay_bytes(&self) -> usize {
        self.replay.bytes_used()
    }

    /// Freeze the tenant into a restorable snapshot. Parked events (the
    /// sequence-reorder buffer) are captured too, so a tenant can be
    /// spilled mid-reorder without dropping its parked tail — their
    /// submit stamps are NOT preserved (an `Instant` has no meaning
    /// across a process boundary), so those events simply drop out of
    /// the latency accounting.
    pub fn snapshot(&self) -> Result<TenantSnapshot> {
        Ok(TenantSnapshot {
            cfg: self.cfg,
            params: self.params.clone(),
            replay: self.replay.clone(),
            rng: self.rng.clone(),
            metrics: self.metrics,
            next_seq: self.next_seq,
            parked: self
                .parked
                .iter()
                .map(|(&seq, (lat, lab, _))| (seq, lat.clone(), lab.clone()))
                .collect(),
        })
    }

    /// Rebuild a tenant whose cold-tier snapshot proved unrecoverable:
    /// fresh adaptive params, an **empty** replay memory at the
    /// configured geometry, the same RNG derivation as [`Tenant::new`],
    /// and the pre-spill sequence position so in-flight events keep
    /// applying in order. The learned trajectory is lost — that is the
    /// explicit accuracy cost [`GovernorAction::Degrade`] logs — but the
    /// tenant keeps serving, which is the survival contract.
    ///
    /// [`GovernorAction::Degrade`]: crate::fleet::governor::GovernorAction::Degrade
    pub fn degraded(
        id: TenantId,
        be: &dyn Backend,
        cfg: CLConfig,
        next_seq: u64,
        metrics: TenantMetrics,
    ) -> Result<Tenant> {
        let m = be.manifest();
        let lat = m.latent_info(cfg.l)?;
        let latent_elems = lat.elems();
        let a_max = lat.a_max(cfg.int8_frozen);
        let params = be.load_params(cfg.l)?;
        let replay = if cfg.lr_bits == 32 {
            ReplayBuffer::new_f32(cfg.n_lr, latent_elems)
        } else {
            ReplayBuffer::new_packed(cfg.n_lr, latent_elems, cfg.lr_bits, a_max)
        };
        let rng = Rng::new(cfg.seed ^ m.seed.wrapping_mul(0x9E37));
        Ok(Tenant {
            id,
            cfg,
            params,
            replay,
            batcher: Batcher::new(m.batch_train, m.batch_new, latent_elems),
            rng,
            metrics,
            next_seq,
            parked: BTreeMap::new(),
            eval_chunk: vec![0.0; m.batch_eval * latent_elems],
            logits_chunk: vec![0.0; m.batch_eval * m.num_classes],
            batch_eval: m.batch_eval,
        })
    }

    /// Rebuild a tenant from a snapshot under a (possibly new) slot id.
    pub fn restore(id: TenantId, be: &dyn Backend, snap: TenantSnapshot) -> Result<Tenant> {
        let m = be.manifest();
        ensure!(
            snap.replay.latent_elems() == m.latent_info(snap.cfg.l)?.elems(),
            "snapshot latent size does not match this backend"
        );
        let latent_elems = snap.replay.latent_elems();
        let mut parked = BTreeMap::new();
        for (seq, lat, lab) in snap.parked {
            ensure!(
                seq >= snap.next_seq && !parked.contains_key(&seq),
                "snapshot parked event seq {seq} inconsistent with next_seq {}",
                snap.next_seq
            );
            ensure!(
                lab.len() * latent_elems == lat.len() && !lab.is_empty(),
                "snapshot parked event {seq} is ragged"
            );
            parked.insert(seq, (lat, lab, None));
        }
        Ok(Tenant {
            id,
            cfg: snap.cfg,
            params: snap.params,
            replay: snap.replay,
            batcher: Batcher::new(m.batch_train, m.batch_new, latent_elems),
            rng: snap.rng,
            metrics: snap.metrics,
            next_seq: snap.next_seq,
            parked,
            eval_chunk: vec![0.0; m.batch_eval * latent_elems],
            logits_chunk: vec![0.0; m.batch_eval * m.num_classes],
            batch_eval: m.batch_eval,
        })
    }
}

/// Everything needed to resurrect an evicted tenant — adaptive params,
/// replay memory (still quantized), RNG state, counters, and any parked
/// (sequence-reorder) events. The frozen backbone is NOT here: it lives
/// once per host, which is exactly why eviction/restore cycles are
/// cheap.
#[derive(Clone)]
pub struct TenantSnapshot {
    pub cfg: CLConfig,
    pub params: ParamState,
    pub replay: ReplayBuffer,
    pub rng: Rng,
    pub metrics: TenantMetrics,
    pub next_seq: u64,
    /// early arrivals captured mid-reorder: `(seq, latents, labels)`,
    /// ascending by seq
    pub parked: Vec<(u64, Vec<f32>, Vec<i32>)>,
}

impl TenantSnapshot {
    /// Bytes the snapshot's elastic state will charge on restore.
    pub fn replay_bytes(&self) -> usize {
        self.replay.bytes_used()
    }

    /// One past the highest sequence number this snapshot knows about —
    /// what a fresh slot's submit counter must be at least, so future
    /// stamps cannot collide with the captured parked events.
    pub fn seq_ceiling(&self) -> u64 {
        self.parked.last().map(|p| p.0 + 1).unwrap_or(0).max(self.next_seq)
    }
}
