//! The fleet server: N independent continual learners per host, one
//! shared frozen backbone, one global memory budget.
//!
//! ## Architecture
//!
//! - **Shared backbone** ([`SharedBackend`]): frozen weights + PTQ
//!   calibration + kernel engine, loaded once, shared via `Arc`. Tenants
//!   hold only adaptive params + replay memory + RNG (Pellegrini et
//!   al.'s frozen/adaptive split is what makes this safe).
//! - **Ingress** ([`super::ingress::Bounded`]): a bounded MPSC of
//!   [`FleetEvent`]s. Workers pop *batches* and coalesce the frozen
//!   forward across tenants into ONE engine call
//!   ([`FrozenCoalescer`]), so frozen-stage throughput scales with batch
//!   width, not tenant count. Stage B dispatches each event's latents to
//!   its tenant's adaptive stage.
//! - **Ordering/determinism**: events carry a per-tenant sequence number
//!   assigned at submit; tenants apply strictly in sequence (parking
//!   early arrivals). Per-tenant outcomes depend only on (tenant seed,
//!   tenant event order, shared backbone) — the engine is bit-exact
//!   per row regardless of batch composition and thread count — so
//!   **accuracy is identical for any worker count**, and a fleet of one
//!   reproduces `run_protocol` bit-for-bit (`rust/tests/fleet.rs`).
//! - **Governor** ([`MemoryGovernor`]): global byte budget (default
//!   64 MB), run as a three-tier replay hierarchy. Admissions that would
//!   blow it demote the coldest tenants' replay memories 8→7-bit in
//!   place, then (when a spill directory is configured) serialize whole
//!   cold tenants to disk, then shrink slot counts; every action is
//!   logged. A spilled tenant keeps its slot, its submit counter and its
//!   sequence parking, and is **lazily restored** on its next event —
//!   with the *lossless* spill-only relief mode, so mid-run governor
//!   activity never alters replay contents and per-tenant outcomes stay
//!   independent of worker scheduling. When pressure clears,
//!   [`FleetServer::rebalance`] walks the ladder back up (readmit
//!   spilled tenants, re-widen 7→8-bit) under watermark hysteresis.
//!
//! ## Lock order
//!
//! `admin` (governor + spill registry + slot directory) before any
//! tenant lock; tenant locks in ascending slot order when holding
//! several (batched inference). Workers take one tenant lock at a time
//! on the hot path, and take `admin` (never while holding a tenant
//! lock) only to lazily restore a spilled tenant.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::batcher::FrozenCoalescer;
use crate::coordinator::metrics::{LatencySummary, RobustnessSummary};
use crate::coordinator::replay::ReplayBuffer;
use crate::coordinator::trainer::CLConfig;
use crate::models::{memory, NetDesc};
use crate::runtime::native::net_from_manifest;
use crate::runtime::SharedBackend;
use crate::telemetry::{
    log_enabled, Counter, EventKind, Gauge, Path as TmPath, Telemetry, TelemetryReport,
    LANE_HIGH, LANE_LOW, LANE_NONE, TENANT_NONE,
};

use super::faults::{DirectIo, FaultPlan, FaultyIo, RetryPolicy, SpillIo};
use super::governor::{
    GovernorAction, GovernorConfig, GovernorTally, MemoryGovernor, PlannedAction, PlannedBoost,
    ReliefMode, SpilledFootprint, TenantFootprint,
};
use super::ingress::Bounded;
use super::snapshot;
use super::tenant::{Tenant, TenantConfig, TenantId, TenantSnapshot};

/// Server-wide deployment knobs. The split and frozen mode are fleet
/// level — one shared backbone implies one latent geometry.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// first adaptive layer (one of the manifest splits)
    pub l: usize,
    /// frozen stage: INT-8 (true) or FP32 baseline
    pub int8_frozen: bool,
    /// governor policy (budget, demotion floor, shrink floor, watermarks)
    pub governor: GovernorConfig,
    /// slot table size — the hard cap on concurrently resident tenants
    pub max_tenants: usize,
    /// bounded ingress depth (events in flight before submit blocks)
    pub queue_depth: usize,
    /// max events one worker coalesces into a single frozen call
    pub coalesce: usize,
    /// cold-tier directory: when set, the governor may spill whole
    /// tenants to versioned snapshot files here instead of (lossily)
    /// shrinking them, and the server restores them lazily on their
    /// next event. `None` disables the disk tier (the pre-spill ladder).
    pub spill_dir: Option<PathBuf>,
    /// deterministic fault-injection schedule (chaos runs only);
    /// [`FaultPlan::none`] — the default — injects nothing and costs one
    /// branch per hook
    pub faults: FaultPlan,
    /// bounded retry-with-backoff policy for cold-tier spill/restore I/O
    pub retry: RetryPolicy,
    /// ingress admission control: block (backpressure) or shed with an
    /// explicit per-tenant overload response
    pub admission: Admission,
    /// the unified execution-pool configuration (`TINYCL_THREADS`):
    /// `--workers 0` / "auto" worker counts resolve to `exec.threads`,
    /// and serving workers run as tasks on the shared persistent pool
    pub exec: crate::exec::ExecConfig,
    /// telemetry sink: spans, latency histograms and SLO counters.
    /// [`Telemetry::none`] — the default — records nothing and costs one
    /// branch per hook (the `FaultPlan::none` discipline); recording
    /// never changes fleet outcomes (`rust/tests/telemetry.rs`). `run`
    /// installs an enabled handle process-globally for its duration so
    /// kernel- and pool-level spans land in the same sink.
    pub telemetry: Telemetry,
}

impl FleetConfig {
    pub fn new(l: usize) -> FleetConfig {
        FleetConfig {
            l,
            int8_frozen: true,
            governor: GovernorConfig::default(),
            max_tenants: 256,
            queue_depth: 1024,
            coalesce: 8,
            spill_dir: None,
            faults: FaultPlan::none(),
            retry: RetryPolicy::default(),
            admission: Admission::Block,
            exec: crate::exec::ExecConfig::from_env(),
            telemetry: Telemetry::none(),
        }
    }
}

/// What `run`'s submitting thread does when the ingress queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// block until a slot frees (classic backpressure — the default, and
    /// the bit-stable mode the determinism suite pins)
    Block,
    /// wait at most `max_wait_ms` for a slot, then shed the event with a
    /// [`Rejected::Overloaded`] response instead of blocking the
    /// submitter indefinitely
    Shed { max_wait_ms: u64 },
}

/// An admission-control rejection recorded during a serving run
/// (retrieve them with [`FleetServer::take_rejections`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// the ingress queue stayed full past the shed deadline; the caller
    /// should retry this tenant's event after `retry_after_ms`
    /// (exponential per consecutive shed, reset on the next admit)
    Overloaded { tenant: TenantId, retry_after_ms: u64 },
}

impl Rejected {
    pub fn tenant(&self) -> TenantId {
        match self {
            Rejected::Overloaded { tenant, .. } => *tenant,
        }
    }

    /// The suggested client backoff before resubmitting this tenant.
    pub fn retry_after_ms(&self) -> u64 {
        match self {
            Rejected::Overloaded { retry_after_ms, .. } => *retry_after_ms,
        }
    }
}

/// The graceful-degradation ladder position, derived from the pressure
/// counter (sheds, exhausted I/O retries, degrades since the last
/// [`FleetServer::clear_pressure`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServiceLevel {
    /// no recorded pressure: full-fidelity evaluation
    Full,
    /// sustained pressure: evaluate on a deterministic strided subset of
    /// the test split (cheaper, approximate)
    Sampled,
    /// heavy pressure: refuse maintenance work outright so serving keeps
    /// the host — eval returns [`EvalOutcome::Deferred`], rebalance
    /// defers
    Deferred,
}

/// What [`FleetServer::evaluate_tenant_adaptive`] produced under the
/// current [`ServiceLevel`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EvalOutcome {
    /// full test split
    Full(f64),
    /// strided subset (every [`EVAL_SAMPLE_STRIDE`]-th test row)
    Sampled(f64),
    /// not evaluated — retry after pressure clears
    Deferred,
}

/// Completion handle of a background eval sweep started with
/// [`FleetServer::evaluate_tenants_async`]: the per-tenant jobs run on
/// the execution pool's low lane while the caller keeps serving; `wait`
/// joins and returns the accuracies in the submitted tenant order.
/// Dropping the handle unwaited still blocks until the sweep finishes
/// (the jobs borrow the server).
pub struct EvalHandle<'s> {
    inner: crate::exec::GroupHandle<'s, Result<f64>>,
}

impl EvalHandle<'_> {
    /// Block until every tenant is scored; first per-tenant error wins.
    pub fn wait(self) -> Result<Vec<f64>> {
        self.inner.wait().into_iter().collect()
    }
}

/// Stride of the sampled-eval subset (every 4th test row).
pub const EVAL_SAMPLE_STRIDE: usize = 4;

/// Pressure thresholds for the ladder: `Sampled` at the first recorded
/// incident, `Deferred` from the eighth.
const PRESSURE_DEFER: u64 = 8;

/// One training event: a batch of fresh images for one tenant (the
/// fleet-side analogue of a NICv2 learning event).
pub struct FleetEvent {
    pub tenant: TenantId,
    /// `[n, hw, hw, 3]` f32 images in `[0, 1]`
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    /// per-tenant sequence number (stamped at submit)
    seq: u64,
    submitted: Option<Instant>,
}

impl FleetEvent {
    pub fn new(tenant: TenantId, images: Vec<f32>, labels: Vec<i32>) -> FleetEvent {
        FleetEvent { tenant, images, labels, seq: 0, submitted: None }
    }

    /// Build an event from one `(class, session)` of a dataset — the
    /// offline driver's bridge from the NICv2 protocol to fleet traffic.
    pub fn from_dataset(
        ds: &crate::runtime::Dataset,
        tenant: TenantId,
        class: usize,
        session: usize,
    ) -> FleetEvent {
        let indices = ds.event_indices(class, session);
        let img = ds.image_elems();
        let mut images = vec![0f32; indices.len() * img];
        let mut labels = vec![0i32; indices.len()];
        for (i, &idx) in indices.iter().enumerate() {
            ds.train_image_into(idx, &mut images[i * img..(i + 1) * img]);
            labels[i] = ds.train_labels[idx];
        }
        FleetEvent::new(tenant, images, labels)
    }
}

/// One batched-inference request: images for one tenant's current model.
pub struct InferRequest<'a> {
    pub tenant: TenantId,
    pub images: &'a [f32],
}

struct TenantSlot {
    tenant: Mutex<Option<Tenant>>,
    /// next sequence number handed out at submit
    submit_seq: AtomicU64,
    /// logical-clock stamp of the latest submitted event — governor
    /// coldness. An atomic on the slot (not a field behind the tenant
    /// lock) so submission never blocks on a tenant mid-training, and a
    /// LOGICAL clock (not wall time) so governor decisions are a pure
    /// function of the submission sequence — the determinism tests lean
    /// on that.
    last_active: AtomicU64,
}

/// End-of-run summary: throughput, latency percentiles, coalescing and
/// governor tallies (what `BENCH_fleet.json` records).
#[derive(Clone, Debug, Default)]
pub struct FleetReport {
    pub events: u64,
    pub dropped: u64,
    pub wall_s: f64,
    pub events_per_sec: f64,
    pub latency: LatencySummary,
    pub frozen_calls: u64,
    pub frozen_rows: u64,
    /// mean events fused per frozen call (cross-tenant batching factor)
    pub mean_coalesce: f64,
    /// spilled tenants transparently readmitted from disk by the
    /// serving path during this run (the lazy-restore count)
    pub lazy_restores: u64,
    /// survival accounting for this run: sheds, I/O retries, degrades
    pub robustness: RobustnessSummary,
    /// telemetry digest of the run — `None` when
    /// [`FleetConfig::telemetry`] is disabled
    pub telemetry: Option<TelemetryReport>,
}

/// What [`FleetServer::rebalance`] actually executed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RebalanceOutcome {
    /// spilled tenants readmitted from the cold tier
    pub unspilled: usize,
    /// resident tenants re-widened 7→8-bit
    pub promoted: usize,
    /// the whole pass was skipped: the degradation ladder sits at
    /// [`ServiceLevel::Deferred`] and maintenance must not stall serving
    pub deferred: bool,
}

/// Cold-tier registry entry: one spilled tenant's snapshot on disk.
struct Spilled {
    path: PathBuf,
    /// RAM bytes a readmission recharges (overhead + replay; equals the
    /// bytes the spill freed — the snapshot round-trips bit-exact)
    ram_bytes: usize,
    /// encoded snapshot size on disk (the governor's cold-tier charge)
    disk_bytes: usize,
    /// metrics at spill time, stashed so [`FleetServer::tenant_metrics`]
    /// can answer without decoding the whole snapshot from disk
    metrics: super::tenant::TenantMetrics,
    /// CL config at spill time, stashed so a degrade (unrecoverable
    /// snapshot) can rebuild the tenant at its deployed geometry without
    /// needing the very bytes that just failed to decode
    cfg: CLConfig,
    /// spill generation: bumped on every spill, so a restore that
    /// decoded the snapshot OUTSIDE the admin lock can detect that the
    /// tenant was restored and re-spilled meanwhile (same path, newer
    /// content) and must re-read rather than install stale state
    generation: u64,
}

/// Admission-control state behind the `admin` lock: the governor's
/// accounting plus the spill registry (which tenant is parked in which
/// file). One lock, so budget math and tier membership can never skew.
struct AdminState {
    gov: MemoryGovernor,
    spilled: BTreeMap<TenantId, Spilled>,
    /// monotonically increasing spill-generation counter
    next_generation: u64,
}

/// Move an unusable spill file aside (never delete — the bytes may still
/// matter for forensics) and log why. The destination never clobbers an
/// earlier quarantined file (`rename` overwrites on Linux): if
/// `<file>.quarantine` exists, a numeric suffix is appended. Best-effort:
/// a failed rename still logs, and the scan simply skips the file.
fn quarantine_spill(path: &Path, reason: &str) {
    let mut qpath = PathBuf::new();
    for attempt in 0..1000u32 {
        let mut name = path.as_os_str().to_owned();
        name.push(".quarantine");
        if attempt > 0 {
            name.push(format!(".{attempt}"));
        }
        qpath = PathBuf::from(name);
        if !qpath.exists() {
            break;
        }
    }
    if std::fs::rename(path, &qpath).is_ok() {
        eprintln!(
            "[fleet] spill recovery: quarantined {} -> {} ({reason})",
            path.display(),
            qpath.display()
        );
    } else {
        eprintln!(
            "[fleet] spill recovery: could not quarantine {} ({reason})",
            path.display()
        );
    }
}

pub struct FleetServer {
    be: SharedBackend,
    cfg: FleetConfig,
    net: NetDesc,
    slots: Box<[TenantSlot]>,
    admin: Mutex<AdminState>,
    /// logical clock: one tick per submitted event (governor coldness)
    clock: AtomicU64,
    latent_elems: usize,
    image_elems: usize,
    /// per-tenant fixed overhead (adaptive params + grads + one training
    /// mini-batch of activations) from the §III-B memory model
    tenant_overhead: usize,
    /// shared-backbone bytes charged once
    shared_bytes: usize,
    /// test-split latents, computed once and shared fleet-wide (the
    /// frozen stage is identical for every tenant)
    test_cache: Mutex<Option<Arc<(Vec<f32>, Vec<i32>)>>>,
    /// strided subset of the test cache for sampled (degraded) eval
    sampled_cache: Mutex<Option<Arc<(Vec<f32>, Vec<i32>)>>>,
    latency_ns: Mutex<Vec<f64>>,
    frozen_calls: AtomicU64,
    frozen_rows: AtomicU64,
    events_done: AtomicU64,
    events_dropped: AtomicU64,
    lazy_restores: AtomicU64,
    /// cold-tier I/O seam: direct in production, fault-injecting under a
    /// chaos plan — all spill/restore bytes flow through it
    io: Box<dyn SpillIo>,
    /// stable operation ids for the fault schedule (one per logical
    /// write/read, shared across its retry attempts)
    write_ops: AtomicU64,
    read_ops: AtomicU64,
    /// degradation-ladder pressure: incidents since `clear_pressure`
    pressure: AtomicU64,
    shed: AtomicU64,
    io_retries: AtomicU64,
    degrades: AtomicU64,
    rejections: Mutex<Vec<Rejected>>,
}

impl FleetServer {
    pub fn new(be: SharedBackend, cfg: FleetConfig) -> Result<FleetServer> {
        let m = be.manifest();
        let lat = m
            .latent_info(cfg.l)
            .with_context(|| format!("fleet split l={} not in the manifest", cfg.l))?;
        let latent_elems = lat.elems();
        let image_elems = m.input_hw * m.input_hw * 3;
        let net = net_from_manifest(m)?;
        let frozen_bits = if cfg.int8_frozen { 8 } else { 32 };
        // per-tenant overhead: the §III-B breakdown at n_lr = 0 minus the
        // shared frozen stage (LR bytes are charged live, per buffer).
        // Labeling conversion: `cfg.l` is a RUNTIME split (first retrained
        // layer); memory::breakdown speaks Table-III LR-layer labeling —
        // row `l-1` for interior splits, the Linear row for the pooled
        // split (see NetDesc::lr_elems). Either way frozen = layers[..l].
        let n_conv = net.layers.len() - 1;
        let table_l = if cfg.l >= n_conv { n_conv } else { cfg.l.max(1) - 1 };
        let q = memory::QuantSetting { frozen_bits, lr_bits: 8 };
        let bd = memory::breakdown(&net, table_l, 0, q, m.batch_train);
        let tenant_overhead = bd.total() - bd.frozen_param_bytes;
        let shared_bytes = bd.frozen_param_bytes;
        ensure!(cfg.max_tenants >= 1, "fleet needs at least one tenant slot");
        ensure!(
            shared_bytes <= cfg.governor.budget_bytes,
            "shared backbone ({shared_bytes} B) alone exceeds the governor budget ({} B)",
            cfg.governor.budget_bytes
        );
        if let Some(dir) = &cfg.spill_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating spill directory {}", dir.display()))?;
        }
        let slots = (0..cfg.max_tenants)
            .map(|_| TenantSlot {
                tenant: Mutex::new(None),
                submit_seq: AtomicU64::new(0),
                last_active: AtomicU64::new(0),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        let admin = AdminState {
            gov: MemoryGovernor::new(cfg.governor, shared_bytes),
            spilled: BTreeMap::new(),
            next_generation: 0,
        };
        let io: Box<dyn SpillIo> = if cfg.faults.is_enabled() {
            Box::new(FaultyIo::new(cfg.faults.clone()))
        } else {
            Box::new(DirectIo)
        };
        let server = FleetServer {
            be,
            cfg,
            net,
            slots,
            admin: Mutex::new(admin),
            clock: AtomicU64::new(0),
            latent_elems,
            image_elems,
            tenant_overhead,
            shared_bytes,
            test_cache: Mutex::new(None),
            sampled_cache: Mutex::new(None),
            latency_ns: Mutex::new(Vec::new()),
            frozen_calls: AtomicU64::new(0),
            frozen_rows: AtomicU64::new(0),
            events_done: AtomicU64::new(0),
            events_dropped: AtomicU64::new(0),
            lazy_restores: AtomicU64::new(0),
            io,
            write_ops: AtomicU64::new(0),
            read_ops: AtomicU64::new(0),
            pressure: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            io_retries: AtomicU64::new(0),
            degrades: AtomicU64::new(0),
            rejections: Mutex::new(Vec::new()),
        };
        if server.cfg.spill_dir.is_some() {
            server.recover_spill_registry()?;
        }
        Ok(server)
    }

    /// Crash-recovery scan of the spill directory: the spill registry is
    /// in-memory, so snapshots written by a previous (crashed) server
    /// process would otherwise be orphaned on disk. At start, enumerate
    /// `tenant_<id>.tcsn` files, validate each snapshot fully (header,
    /// checksum, structural invariants, fleet split/mode), rebuild the
    /// cold-tier registry — slot submit counters restored past every
    /// captured sequence, disk bytes recharged to the governor — and
    /// quarantine anything corrupt or incompatible by renaming it to
    /// `*.quarantine` with a log line. Leftover `*.tmp` files are
    /// hygiene only: the durable write protocol (write-tmp + fsync +
    /// atomic rename in `snapshot::write_bytes`) guarantees a tmp
    /// sibling is never load-bearing — the published snapshot it was
    /// going to replace is intact — so the sweep just reclaims the disk.
    fn recover_spill_registry(&self) -> Result<usize> {
        let dir = self.cfg.spill_dir.as_ref().expect("caller checked spill_dir");
        let mut admin = self.admin.lock().unwrap();
        let mut entries: Vec<(TenantId, PathBuf)> = Vec::new();
        let listing = std::fs::read_dir(dir)
            .with_context(|| format!("scanning spill directory {}", dir.display()))?;
        for entry in listing.flatten() {
            let path = entry.path();
            let name = match path.file_name().and_then(|n| n.to_str()) {
                Some(n) => n.to_string(),
                None => continue,
            };
            if name.ends_with(".tmp") {
                eprintln!(
                    "[fleet] spill recovery: removing abandoned partial write {}",
                    path.display()
                );
                std::fs::remove_file(&path).ok();
                continue;
            }
            let id = name
                .strip_prefix("tenant_")
                .and_then(|r| r.strip_suffix(".tcsn"))
                .and_then(|s| s.parse::<TenantId>().ok());
            if let Some(id) = id {
                entries.push((id, path));
            }
        }
        entries.sort();
        let mut recovered = 0;
        for (id, path) in entries {
            if id >= self.slots.len() {
                quarantine_spill(&path, "tenant id beyond the slot table");
                continue;
            }
            let snap = match snapshot::read_file(&path) {
                Ok(snap) => snap,
                Err(e) => {
                    quarantine_spill(&path, &format!("{e:#}"));
                    continue;
                }
            };
            if snap.cfg.l != self.cfg.l || snap.cfg.int8_frozen != self.cfg.int8_frozen {
                quarantine_spill(&path, "snapshot split/mode does not match this fleet");
                continue;
            }
            if snap.replay.latent_elems() != self.latent_elems {
                quarantine_spill(&path, "snapshot latent size does not match this fleet");
                continue;
            }
            let disk_bytes = std::fs::metadata(&path).map(|m| m.len() as usize).unwrap_or(0);
            let ram_bytes = self.tenant_overhead + snap.replay_bytes();
            // the fresh slot's submit counter must clear every sequence
            // the snapshot knows about, exactly as restore() guarantees
            self.slots[id].submit_seq.store(snap.seq_ceiling(), Ordering::Relaxed);
            self.slots[id]
                .last_active
                .store(self.clock.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
            let generation = admin.next_generation;
            admin.next_generation += 1;
            admin.spilled.insert(
                id,
                Spilled {
                    path: path.clone(),
                    ram_bytes,
                    disk_bytes,
                    metrics: snap.metrics,
                    cfg: snap.cfg,
                    generation,
                },
            );
            self.commit_gov(&mut admin, GovernorAction::Recover { tenant: id, disk_bytes });
            if log_enabled() {
                eprintln!(
                    "[fleet] spill recovery: re-registered tenant {id} from {} \
                     ({disk_bytes} B on disk)",
                    path.display()
                );
            }
            recovered += 1;
        }
        Ok(recovered)
    }

    pub fn backend(&self) -> &SharedBackend {
        &self.be
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    pub fn net(&self) -> &NetDesc {
        &self.net
    }

    /// Per-tenant fixed overhead the governor charges on top of the live
    /// replay bytes.
    pub fn tenant_overhead_bytes(&self) -> usize {
        self.tenant_overhead
    }

    /// RAM bytes one tenant of this shape charges at admission (fixed
    /// overhead + a fresh replay buffer at this fleet's latent size) —
    /// exactly the `needed` figure [`FleetServer::admit_prepared`] asks
    /// the governor for. The one source of truth drivers should use to
    /// size budgets instead of re-assembling the sum themselves.
    pub fn per_tenant_bytes(&self, n_lr: usize, lr_bits: u8) -> usize {
        self.tenant_overhead + ReplayBuffer::bytes_for(n_lr, self.latent_elems, lr_bits)
    }

    /// Shared-backbone bytes charged once per host.
    pub fn shared_backbone_bytes(&self) -> usize {
        self.shared_bytes
    }

    pub fn bytes_in_use(&self) -> usize {
        self.admin.lock().unwrap().gov.bytes_in_use()
    }

    /// Snapshot bytes currently parked in the cold (disk) tier.
    pub fn spilled_disk_bytes(&self) -> usize {
        self.admin.lock().unwrap().gov.spilled_disk_bytes()
    }

    pub fn governor_log(&self) -> Vec<GovernorAction> {
        self.admin.lock().unwrap().gov.log().to_vec()
    }

    /// Per-flavor action counts from the governor log.
    pub fn governor_tally(&self) -> GovernorTally {
        self.admin.lock().unwrap().gov.tally()
    }

    /// Tenants currently resident in RAM (hot or warm tier).
    pub fn tenant_count(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.tenant.lock().unwrap().is_some())
            .count()
    }

    /// Tenants currently parked in the cold (disk) tier.
    pub fn spilled_count(&self) -> usize {
        self.admin.lock().unwrap().spilled.len()
    }

    /// Ids of tenants currently resident in RAM, ascending.
    pub fn resident_ids(&self) -> Vec<TenantId> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.tenant.lock().unwrap().is_some())
            .map(|(id, _)| id)
            .collect()
    }

    /// Ids of tenants currently spilled to disk, ascending.
    pub fn spilled_ids(&self) -> Vec<TenantId> {
        self.admin.lock().unwrap().spilled.keys().copied().collect()
    }

    /// Recompute the governor's charge from live state — shared backbone
    /// plus, per resident tenant, the fixed overhead and the actual
    /// replay-buffer bytes. Tests assert this equals
    /// [`FleetServer::bytes_in_use`] (the incrementally tracked total)
    /// after any sequence of admits/demotes/shrinks/evicts.
    pub fn recompute_bytes(&self) -> usize {
        let mut total = self.shared_bytes;
        for slot in self.slots.iter() {
            if let Some(t) = slot.tenant.lock().unwrap().as_ref() {
                total += self.tenant_overhead + t.replay_bytes();
            }
        }
        total
    }

    /// Single sink for governor commits: push to the governor's action
    /// log, mirror one `governor.action` event into the telemetry stream
    /// (key = log index, so a trace lines up with
    /// [`FleetServer::governor_log`]), refresh the tier gauges, and —
    /// behind `TINYCL_LOG` — render a human-readable line.
    fn commit_gov(&self, admin: &mut AdminState, action: GovernorAction) {
        let tm = &self.cfg.telemetry;
        if tm.is_enabled() {
            tm.event_ns(
                EventKind::Governor,
                admin.gov.log().len() as u64,
                action.tenant_id().map_or(TENANT_NONE, |t| t as u32),
                LANE_NONE,
                0,
                action.kind_tag(),
                action.bytes_moved(),
            );
            tm.counter_add(Counter::GovActions, 1);
        }
        if log_enabled() {
            eprintln!("[governor] {}", action.describe());
        }
        admin.gov.commit(action);
        if tm.is_enabled() {
            let ram = admin.gov.bytes_in_use() as u64;
            tm.gauge_set(Gauge::GovRamBytes, ram);
            tm.gauge_max(Gauge::GovRamPeakBytes, ram);
            tm.gauge_set(Gauge::GovDiskBytes, admin.gov.spilled_disk_bytes() as u64);
        }
    }

    // ---- admission control ----------------------------------------------

    /// Relief mode for admission-time pressure: the full three-tier
    /// ladder when a spill directory is configured, degrade-only
    /// otherwise.
    fn admit_mode(&self) -> ReliefMode {
        if self.cfg.spill_dir.is_some() {
            ReliefMode::DegradeAndSpill
        } else {
            ReliefMode::Degrade
        }
    }

    /// Snapshot file path for one tenant in the cold tier.
    fn spill_path(&self, id: TenantId) -> Result<PathBuf> {
        let dir = self
            .cfg
            .spill_dir
            .as_ref()
            .ok_or_else(|| anyhow!("no spill directory configured"))?;
        Ok(dir.join(format!("tenant_{id}.tcsn")))
    }

    // ---- hardened cold-tier I/O ------------------------------------------

    /// Record one pressure incident (shed, exhausted retry, degrade) —
    /// moves the degradation ladder toward Sampled/Deferred. Public so
    /// embedders can fold EXTERNAL overload signals (host memory
    /// pressure, upstream queue depth) into the same ladder.
    pub fn note_pressure(&self) {
        self.pressure.fetch_add(1, Ordering::Relaxed);
    }

    /// Reset the degradation ladder to [`ServiceLevel::Full`] (call once
    /// the overload/fault episode has passed).
    pub fn clear_pressure(&self) {
        self.pressure.store(0, Ordering::Relaxed);
    }

    /// Current rung of the graceful-degradation ladder.
    pub fn service_level(&self) -> ServiceLevel {
        match self.pressure.load(Ordering::Relaxed) {
            0 => ServiceLevel::Full,
            n if n < PRESSURE_DEFER => ServiceLevel::Sampled,
            _ => ServiceLevel::Deferred,
        }
    }

    /// Admission-control rejections recorded since the last call (the
    /// fleet-side `Rejected::Overloaded` responses).
    pub fn take_rejections(&self) -> Vec<Rejected> {
        std::mem::take(&mut *self.rejections.lock().unwrap())
    }

    /// The governor's CURRENT budget (differs from the configured one
    /// after a budget shock).
    pub fn budget_bytes(&self) -> usize {
        self.admin.lock().unwrap().gov.config().budget_bytes
    }

    /// Events shed by admission control since this server was built.
    pub fn sheds(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Events fully applied since this server was built.
    pub fn events_applied(&self) -> u64 {
        self.events_done.load(Ordering::Relaxed)
    }

    /// Durable spill write with bounded retry + exponential backoff. One
    /// logical operation (a stable op id shared by every attempt), up to
    /// `retry.attempts` tries; transient faults (EIO/ENOSPC/torn writes)
    /// are retried, and the write-tmp + fsync + rename protocol means a
    /// failed attempt can never shadow a previously published snapshot.
    fn spill_write(&self, path: &Path, snap: &TenantSnapshot) -> Result<usize> {
        let op = self.write_ops.fetch_add(1, Ordering::Relaxed);
        let tm = &self.cfg.telemetry;
        // span key = the fault injector's op id, so a trace lines up
        // with a chaos replay of the same seed
        let mut sp = tm.span(EventKind::SpillWrite).key(op).hist(TmPath::SpillWrite);
        tm.counter_add(Counter::SpillWrites, 1);
        let attempts = self.cfg.retry.attempts.max(1);
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                self.io_retries.fetch_add(1, Ordering::Relaxed);
                // yielding backoff: on a pool-resident serving worker
                // the wait drains queued kernel parts instead of idling
                // a shared thread for the whole backoff ladder
                crate::exec::yield_backoff(self.cfg.retry.backoff(attempt));
            }
            match self.io.write_snapshot(path, snap, op, attempt) {
                Ok(n) => {
                    sp.set_payload(n as u64, attempt as u64 + 1);
                    return Ok(n);
                }
                Err(e) => last = Some(e),
            }
        }
        sp.set_payload(0, attempts as u64);
        self.note_pressure();
        Err(last.expect("attempts >= 1")).with_context(|| {
            format!("spill write {} failed after {attempts} attempts", path.display())
        })
    }

    /// Retrying restore read (same policy as [`FleetServer::spill_write`]).
    /// Transient read faults recover on a later attempt; persistent
    /// corruption (the file itself is damaged) exhausts the budget and
    /// surfaces to the caller, whose recourse is the degrade path.
    fn spill_read(&self, path: &Path) -> Result<TenantSnapshot> {
        let op = self.read_ops.fetch_add(1, Ordering::Relaxed);
        let tm = &self.cfg.telemetry;
        let mut sp = tm.span(EventKind::SpillRead).key(op).hist(TmPath::SpillRead);
        tm.counter_add(Counter::SpillReads, 1);
        let attempts = self.cfg.retry.attempts.max(1);
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                self.io_retries.fetch_add(1, Ordering::Relaxed);
                crate::exec::yield_backoff(self.cfg.retry.backoff(attempt));
            }
            match self.io.read_snapshot(path, op, attempt) {
                Ok(snap) => {
                    sp.set_payload(snap.replay_bytes() as u64, attempt as u64 + 1);
                    return Ok(snap);
                }
                Err(e) => last = Some(e),
            }
        }
        sp.set_payload(0, attempts as u64);
        self.note_pressure();
        Err(last.expect("attempts >= 1")).with_context(|| {
            format!("spill read {} failed after {attempts} attempts", path.display())
        })
    }

    /// Survive an unrecoverable cold-tier snapshot: quarantine the file
    /// and rebuild the tenant RESIDENT with an empty replay buffer at
    /// its deployed geometry ([`Tenant::degraded`]). The learned replay
    /// state is lost — [`GovernorAction::Degrade`] logs that explicitly
    /// — but the tenant keeps its slot, its metrics, and its submit
    /// counter, and the budget stays balanced. Room is made BEFORE the
    /// registry entry is removed, so a failed relief leaves the tenant
    /// still spilled (accounted, retryable) rather than lost.
    fn degrade_tenant(
        &self,
        admin: &mut AdminState,
        id: TenantId,
        err: &anyhow::Error,
    ) -> Result<()> {
        let (cfg, spill_metrics) = match admin.spilled.get(&id) {
            Some(rec) => (rec.cfg, rec.metrics),
            None => bail!("tenant {id} is not in the cold tier"),
        };
        let needed = self.tenant_overhead
            + ReplayBuffer::bytes_for(cfg.n_lr, self.latent_elems, cfg.lr_bits);
        self.make_room(admin, needed, "tenant degrade", ReliefMode::SpillOnly)?;
        let rec = admin.spilled.remove(&id).expect("present above; admin lock held");
        quarantine_spill(&rec.path, &format!("unrecoverable restore: {err:#}"));
        // resume at the slot's submit counter: events stamped before the
        // degrade belong to the lost trajectory and are dropped by the
        // dispatch stale-seq guard; events stamped after apply normally
        let next_seq = self.slots[id].submit_seq.load(Ordering::Relaxed);
        let tenant = Tenant::degraded(id, &*self.be, cfg, next_seq, spill_metrics)?;
        let bytes = self.tenant_overhead + tenant.replay_bytes();
        *self.slots[id].tenant.lock().unwrap() = Some(tenant);
        self.slots[id]
            .last_active
            .store(self.clock.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
        self.commit_gov(
            admin,
            GovernorAction::Degrade { tenant: id, bytes, disk_freed: rec.disk_bytes },
        );
        let degrades = self.degrades.fetch_add(1, Ordering::Relaxed) + 1;
        self.note_pressure();
        self.cfg.telemetry.event_ns(
            EventKind::Degrade,
            degrades,
            id as u32,
            LANE_NONE,
            0,
            bytes as u64,
            rec.disk_bytes as u64,
        );
        if log_enabled() {
            eprintln!(
                "[fleet] tenant {id}: cold-tier snapshot unrecoverable ({err:#}); \
                 rebuilt resident with an empty replay buffer"
            );
        }
        Ok(())
    }

    /// Apply a memory-budget shock (factor of the CURRENT budget). A
    /// shrink losslessly spills the coldest tenants until the survivors
    /// fit the new envelope, then resizes it; a growth just resizes.
    /// The envelope never shrinks below the shared backbone.
    fn shock_budget_factor(&self, factor: f64) -> Result<()> {
        let mut admin = self.admin.lock().unwrap();
        let old = admin.gov.config().budget_bytes;
        let new = ((old as f64 * factor) as usize).max(self.shared_bytes);
        if new < old {
            let mode = if self.cfg.spill_dir.is_some() {
                ReliefMode::SpillOnly
            } else {
                ReliefMode::Degrade
            };
            // freeing (old - new) bytes under the old envelope leaves
            // in_use <= new, which is what set_budget requires
            self.make_room(&mut admin, old - new, "budget shock", mode)?;
        }
        admin.gov.set_budget(new);
        if log_enabled() {
            eprintln!("[fleet] budget shock: {old} -> {new} B (x{factor})");
        }
        Ok(())
    }

    /// Footprints of all resident tenants (admin lock held by caller).
    fn footprints(&self) -> Vec<TenantFootprint> {
        let mut out = Vec::new();
        for slot in self.slots.iter() {
            let last_active = slot.last_active.load(Ordering::Relaxed);
            let guard = slot.tenant.lock().unwrap();
            if let Some(t) = guard.as_ref() {
                out.push(TenantFootprint {
                    tenant: t.id,
                    last_active,
                    bits: t.replay.bits(),
                    cfg_bits: t.cfg.lr_bits,
                    slots: t.replay.capacity(),
                    latent_elems: t.replay.latent_elems(),
                    overhead: self.tenant_overhead,
                });
            }
        }
        out
    }

    /// Cold-tier footprints (admin lock held by caller). Coldness reads
    /// the slot's live logical clock, so a spilled tenant that keeps
    /// receiving submissions is readmitted ahead of a silent one.
    fn spilled_footprints(&self, admin: &AdminState) -> Vec<SpilledFootprint> {
        admin
            .spilled
            .iter()
            .map(|(&id, rec)| SpilledFootprint {
                tenant: id,
                last_active: self.slots[id].last_active.load(Ordering::Relaxed),
                ram_bytes: rec.ram_bytes,
            })
            .collect()
    }

    /// Execute a relief plan: lock each victim, demote/shrink its replay
    /// memory in place or serialize it to the cold tier, commit the
    /// measured bytes to the log.
    fn execute_relief(&self, admin: &mut AdminState, plan: &[PlannedAction]) -> Result<()> {
        for action in plan {
            match *action {
                PlannedAction::Demote { tenant, to_bits } => {
                    let mut guard = self.slots[tenant].tenant.lock().unwrap();
                    if let Some(t) = guard.as_mut() {
                        let from_bits = t.replay.bits();
                        if from_bits != 32 && from_bits > to_bits {
                            let freed = t.replay.demote_bits(to_bits);
                            t.metrics.demotions += 1;
                            self.commit_gov(
                                admin,
                                GovernorAction::Demote { tenant, from_bits, to_bits, freed },
                            );
                        }
                    }
                }
                PlannedAction::Spill { tenant } => {
                    let mut guard = self.slots[tenant].tenant.lock().unwrap();
                    // the snapshot captures parked (reorder-buffer)
                    // events too, so a tenant is spillable in ANY state
                    // — only a concurrent eviction makes this a no-op
                    if let Some(t) = guard.as_mut() {
                        t.metrics.spills += 1;
                        let snap = t.snapshot()?;
                        let path = self.spill_path(tenant)?;
                        // a permanently failing write propagates up: the
                        // tenant simply STAYS resident (guard untouched),
                        // so nothing is lost — the caller's admission or
                        // restore fails, not the fleet
                        let disk_bytes = self.spill_write(&path, &snap)?;
                        guard.take();
                        drop(guard);
                        let freed = self.tenant_overhead + snap.replay_bytes();
                        let generation = admin.next_generation;
                        admin.next_generation += 1;
                        admin.spilled.insert(
                            tenant,
                            Spilled {
                                path,
                                ram_bytes: freed,
                                disk_bytes,
                                metrics: snap.metrics,
                                cfg: snap.cfg,
                                generation,
                            },
                        );
                        self.commit_gov(
                            admin,
                            GovernorAction::Spill { tenant, freed, disk_bytes },
                        );
                    }
                }
                PlannedAction::Shrink { tenant, to_slots } => {
                    let mut guard = self.slots[tenant].tenant.lock().unwrap();
                    if let Some(t) = guard.as_mut() {
                        let from_slots = t.replay.capacity();
                        if from_slots > to_slots {
                            let freed = t.replay.shrink_capacity(to_slots);
                            t.metrics.shrinks += 1;
                            self.commit_gov(
                                admin,
                                GovernorAction::Shrink { tenant, from_slots, to_slots, freed },
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Make room for `needed` bytes, walking the coldest tenants down
    /// the tier ladder `mode` allows. Errors if the budget cannot cover
    /// it.
    fn make_room(
        &self,
        admin: &mut AdminState,
        needed: usize,
        what: &str,
        mode: ReliefMode,
    ) -> Result<()> {
        let (plan, feasible) = admin.gov.plan_relief(needed, &self.footprints(), mode);
        if !feasible {
            let short_by = needed.saturating_sub(admin.gov.bytes_free());
            self.commit_gov(admin, GovernorAction::Reject { needed, short_by });
            bail!(
                "{what} needs {needed} B but the governor can only free {} B of its {} B budget",
                admin.gov.bytes_free(),
                admin.gov.config().budget_bytes
            );
        }
        self.execute_relief(admin, &plan)?;
        ensure!(
            admin.gov.bytes_free() >= needed,
            "{what}: relief plan under-delivered ({} B free, {needed} B needed)",
            admin.gov.bytes_free()
        );
        Ok(())
    }

    /// First slot that is neither resident nor parked in the cold tier
    /// (a spilled tenant keeps its slot — handing it out would let a
    /// newcomer capture the spilled tenant's submit counter and squat on
    /// its lazy-restore target).
    fn free_slot(&self, admin: &AdminState) -> Result<TenantId> {
        for (id, slot) in self.slots.iter().enumerate() {
            if slot.tenant.lock().unwrap().is_none() && !admin.spilled.contains_key(&id) {
                return Ok(id);
            }
        }
        bail!("all {} tenant slots occupied", self.slots.len())
    }

    /// Install an already-decoded snapshot back into its slot (admin
    /// lock held by caller, `id` still present in the spill registry):
    /// make room in `mode`, rebuild the tenant in its original slot with
    /// its submit counter untouched, release the disk charge, delete the
    /// file.
    fn install_unspilled(
        &self,
        admin: &mut AdminState,
        id: TenantId,
        snap: TenantSnapshot,
        mode: ReliefMode,
    ) -> Result<()> {
        let rec = admin
            .spilled
            .get(&id)
            .ok_or_else(|| anyhow!("tenant {id} is not in the cold tier"))?;
        let path = rec.path.clone();
        let disk_freed = rec.disk_bytes;
        let needed = self.tenant_overhead + snap.replay_bytes();
        self.make_room(admin, needed, "tenant unspill", mode)?;
        let tenant = Tenant::restore(id, &*self.be, snap)?;
        let bytes = self.tenant_overhead + tenant.replay_bytes();
        *self.slots[id].tenant.lock().unwrap() = Some(tenant);
        // NOTE: submit_seq is deliberately NOT reset — in-flight events
        // stamped while the tenant was cold keep their sequence numbers,
        // and the restored next_seq lines up with them (the parking
        // invariant the lazy-restore path leans on)
        self.slots[id]
            .last_active
            .store(self.clock.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
        admin.spilled.remove(&id);
        self.commit_gov(admin, GovernorAction::Unspill { tenant: id, bytes, disk_freed });
        std::fs::remove_file(&path).ok(); // best-effort: the registry is authoritative
        Ok(())
    }

    /// Readmit one spilled tenant into RAM (admin lock held by caller):
    /// read + decode + re-validate the snapshot, then
    /// [`FleetServer::install_unspilled`]. Maintenance-path variant —
    /// the serving path uses [`FleetServer::try_restore_spilled`], which
    /// decodes outside the lock.
    fn unspill_locked(&self, admin: &mut AdminState, id: TenantId, mode: ReliefMode) -> Result<()> {
        let path = admin
            .spilled
            .get(&id)
            .ok_or_else(|| anyhow!("tenant {id} is not in the cold tier"))?
            .path
            .clone();
        match self.spill_read(&path) {
            Ok(snap) => self.install_unspilled(admin, id, snap, mode),
            // unrecoverable snapshot: survive it — quarantine + rebuild
            // with an empty replay buffer instead of failing the caller
            Err(e) => self.degrade_tenant(admin, id, &e),
        }
    }

    /// Restore `id` from the cold tier if it is spilled. Returns whether
    /// the tenant is resident afterwards (`true` covers both "we
    /// restored it" and "another thread won the race"); `Ok(false)`
    /// means the tenant is simply gone (evicted). Uses the lossless
    /// spill-only relief mode — the serving path must never degrade
    /// replay contents mid-run. Liveness holds because EVERY resident is
    /// a valid spill victim (snapshots capture the parked reorder buffer
    /// too): a restore can only fail if the budget genuinely cannot host
    /// this tenant even with everyone else on disk.
    ///
    /// The snapshot read + decode (the expensive part of a restore) runs
    /// WITHOUT the admin lock, so concurrent workers' restores don't
    /// serialize the fleet on disk I/O; the spill *generation* captured
    /// with the path detects the restored-then-respilled race (same
    /// path, newer content) and forces a re-read instead of installing
    /// stale state.
    fn try_restore_spilled(&self, id: TenantId, lazy: bool) -> Result<bool> {
        loop {
            let (path, generation) = {
                let admin = self.admin.lock().unwrap();
                match admin.spilled.get(&id) {
                    // either never spilled/evicted, or a racing worker
                    // already restored it — check under the admin lock
                    None => return Ok(self.slots[id].tenant.lock().unwrap().is_some()),
                    Some(rec) => (rec.path.clone(), rec.generation),
                }
            };
            let decoded = self.spill_read(&path);
            let mut admin = self.admin.lock().unwrap();
            match admin.spilled.get(&id) {
                None => continue, // raced: restored (or evicted) meanwhile
                Some(rec) if rec.generation != generation => continue, // re-spilled: re-read
                Some(_) => {}
            }
            // registry unchanged since the read, so the decode (or its
            // error — corruption, exhausted I/O retries) is authoritative
            // for this entry
            let snap = match decoded {
                Ok(snap) => snap,
                Err(e) => {
                    // unrecoverable: quarantine + degrade — the tenant
                    // comes back resident (empty replay) instead of the
                    // whole serving run dying on a lying disk
                    self.degrade_tenant(&mut admin, id, &e)?;
                    return Ok(true);
                }
            };
            self.install_unspilled(&mut admin, id, snap, ReliefMode::SpillOnly)?;
            if lazy {
                self.lazy_restores.fetch_add(1, Ordering::Relaxed);
            }
            return Ok(true);
        }
    }

    /// Run `f` on a resident tenant, lazily restoring it from the cold
    /// tier first if needed.
    fn with_resident<R>(
        &self,
        id: TenantId,
        mut f: impl FnMut(&mut Tenant) -> Result<R>,
    ) -> Result<R> {
        ensure!(id < self.slots.len(), "unknown tenant {id}");
        loop {
            {
                let mut guard = self.slots[id].tenant.lock().unwrap();
                if let Some(t) = guard.as_mut() {
                    return f(t);
                }
            }
            ensure!(self.try_restore_spilled(id, false)?, "tenant {id} is not resident");
        }
    }

    /// Run the shared frozen stage over raw images — the admission-side
    /// embedding helper. Fleets seeding many tenants from ONE
    /// pre-deployment pool embed it once and pass the latents to
    /// [`FleetServer::admit_prepared`] per tenant.
    pub fn embed_images(&self, images: &[f32]) -> Result<Vec<f32>> {
        ensure!(
            !images.is_empty() && images.len() % self.image_elems == 0,
            "embed_images: ragged images"
        );
        let rows = images.len() / self.image_elems;
        let mut latents = vec![0f32; rows * self.latent_elems];
        self.be
            .frozen_forward(self.cfg.l, self.cfg.int8_frozen, false, images, &mut latents)?;
        Ok(latents)
    }

    /// Admit a new tenant, seeding its replay memory from pre-deployment
    /// images (run through the shared frozen stage here). Demotes/shrinks
    /// cold tenants if the budget requires it; errors if even full relief
    /// cannot fit the newcomer.
    pub fn admit(
        &self,
        tcfg: TenantConfig,
        init_images: &[f32],
        init_labels: &[i32],
    ) -> Result<TenantId> {
        ensure!(
            init_labels.len() * self.image_elems == init_images.len(),
            "admit: ragged init images"
        );
        let latents = self.embed_images(init_images)?;
        self.admit_prepared(tcfg, &latents, init_labels)
    }

    /// [`FleetServer::admit`] over pre-embedded latents (see
    /// [`FleetServer::embed_images`]).
    pub fn admit_prepared(
        &self,
        tcfg: TenantConfig,
        init_latents: &[f32],
        init_labels: &[i32],
    ) -> Result<TenantId> {
        let needed = self.tenant_overhead
            + ReplayBuffer::bytes_for(tcfg.n_lr, self.latent_elems, tcfg.lr_bits);
        let mut admin = self.admin.lock().unwrap();
        // slot check FIRST: relief (demote/spill/shrink) is irreversible,
        // so a full slot table must fail the admission before cold
        // tenants pay
        let id = self.free_slot(&admin)?;
        self.make_room(&mut admin, needed, "tenant admission", self.admit_mode())?;
        let tenant = Tenant::new(
            id,
            &*self.be,
            self.cfg.l,
            self.cfg.int8_frozen,
            tcfg,
            init_latents,
            init_labels,
        )?;
        let bytes = self.tenant_overhead + tenant.replay_bytes();
        *self.slots[id].tenant.lock().unwrap() = Some(tenant);
        self.slots[id].submit_seq.store(0, Ordering::Relaxed);
        self.slots[id]
            .last_active
            .store(self.clock.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
        self.commit_gov(&mut admin, GovernorAction::Admit { tenant: id, bytes });
        Ok(id)
    }

    /// Clone a quiesced tenant's full state (params, replay, RNG). A
    /// spilled tenant's snapshot is decoded straight from its cold-tier
    /// file — no readmission happens.
    pub fn snapshot(&self, id: TenantId) -> Result<TenantSnapshot> {
        ensure!(id < self.slots.len(), "unknown tenant {id}");
        let admin = self.admin.lock().unwrap();
        if let Some(rec) = admin.spilled.get(&id) {
            return snapshot::read_file(&rec.path);
        }
        let guard = self.slots[id].tenant.lock().unwrap();
        guard
            .as_ref()
            .ok_or_else(|| anyhow!("tenant {id} is not resident"))?
            .snapshot()
    }

    /// Walk the tier ladder back up after pressure clears: readmit
    /// spilled tenants and re-widen 7-bit residents to their configured
    /// width, warmest first, under the governor's watermark hysteresis
    /// (a no-op unless usage sits below the low watermark; boosts stop
    /// at the high watermark). Call it from a maintenance point — after
    /// evictions, between serving runs, on a timer; it is a no-op
    /// whenever the watermarks say so, so calling often is safe.
    pub fn rebalance(&self) -> Result<RebalanceOutcome> {
        if self.service_level() == ServiceLevel::Deferred {
            // heavy pressure: maintenance yields to serving — readmitting
            // tenants right now would fight the very overload episode
            // that raised the pressure. Call again after clear_pressure.
            return Ok(RebalanceOutcome { deferred: true, ..RebalanceOutcome::default() });
        }
        let mut admin = self.admin.lock().unwrap();
        let boosts = admin.gov.plan_boost(&self.footprints(), &self.spilled_footprints(&admin));
        let mut outcome = RebalanceOutcome::default();
        for boost in boosts {
            match boost {
                PlannedBoost::Unspill { tenant } => {
                    // planned under the high-watermark ceiling, so no
                    // relief is needed — but tolerate a racing admission
                    // by skipping instead of spilling someone else
                    let rec_bytes = match admin.spilled.get(&tenant) {
                        Some(rec) => rec.ram_bytes,
                        None => continue, // raced: already restored
                    };
                    if admin.gov.bytes_free() < rec_bytes {
                        continue;
                    }
                    self.unspill_locked(&mut admin, tenant, ReliefMode::SpillOnly)?;
                    outcome.unspilled += 1;
                }
                PlannedBoost::Promote { tenant, to_bits } => {
                    let mut guard = self.slots[tenant].tenant.lock().unwrap();
                    if let Some(t) = guard.as_mut() {
                        let from_bits = t.replay.bits();
                        if from_bits != 32 && from_bits < to_bits {
                            let grew = t.replay.promote_bits(to_bits);
                            t.metrics.promotions += 1;
                            self.commit_gov(
                                &mut admin,
                                GovernorAction::Promote { tenant, from_bits, to_bits, grew },
                            );
                            outcome.promoted += 1;
                        }
                    }
                }
            }
        }
        Ok(outcome)
    }

    /// Remove a tenant, returning its snapshot and releasing its bytes.
    ///
    /// Requires a quiesced tenant: no parked events AND no stamped
    /// events still in flight in the ingress queue — otherwise a later
    /// restore would reuse sequence numbers the in-flight events already
    /// carry (stale data trained as current, or a duplicate-seq error).
    /// Callers must not submit events for a tenant they are concurrently
    /// evicting.
    pub fn evict(&self, id: TenantId) -> Result<TenantSnapshot> {
        ensure!(id < self.slots.len(), "unknown tenant {id}");
        let mut admin = self.admin.lock().unwrap();
        if let Some(rec) = admin.spilled.get(&id) {
            // evicting straight out of the cold tier: hand back the
            // decoded snapshot and release the disk charge — no RAM ever
            // moves. (Unspill{bytes: 0} + Evict{freed: 0} keeps the
            // governor's running totals balanced while recording that
            // the tenant left through the cold tier.)
            let stamped = self.slots[id].submit_seq.load(Ordering::Relaxed);
            let snap = snapshot::read_file(&rec.path)?;
            ensure!(
                stamped == snap.next_seq,
                "tenant {id} has {} stamped event(s) still in flight; drain before evicting",
                stamped - snap.next_seq
            );
            let path = rec.path.clone();
            let disk_freed = rec.disk_bytes;
            admin.spilled.remove(&id);
            self.commit_gov(
                &mut admin,
                GovernorAction::Unspill { tenant: id, bytes: 0, disk_freed },
            );
            self.commit_gov(&mut admin, GovernorAction::Evict { tenant: id, freed: 0 });
            std::fs::remove_file(&path).ok();
            return Ok(snap);
        }
        let mut guard = self.slots[id].tenant.lock().unwrap();
        let resident = guard.as_ref().ok_or_else(|| anyhow!("tenant {id} is not resident"))?;
        let stamped = self.slots[id].submit_seq.load(Ordering::Relaxed);
        ensure!(
            stamped == resident.next_seq(),
            "tenant {id} has {} stamped event(s) still in flight; drain before evicting",
            stamped - resident.next_seq()
        );
        // NOTE: snapshot() no longer refuses parked work (spills carry
        // the reorder buffer); eviction's quiesce guarantee rests on the
        // stamped == next_seq check above, which implies parked is empty
        let snap = resident.snapshot()?;
        guard.take();
        let freed = self.tenant_overhead + snap.replay_bytes();
        self.commit_gov(&mut admin, GovernorAction::Evict { tenant: id, freed });
        Ok(snap)
    }

    /// Failed-run recovery: discard a tenant's parked events (their
    /// predecessors died with the queue) and re-align its submit counter
    /// with its applied counter, so future submissions flow again. A
    /// tenant that was spilled when the run died is restored first —
    /// its snapshot may carry parked events whose predecessors are gone
    /// too. Only sound while no serving run is active.
    pub fn resync_sequences(&self, id: TenantId) -> Result<usize> {
        self.with_resident(id, |t| {
            let dropped = t.drop_parked();
            self.slots[id].submit_seq.store(t.next_seq(), Ordering::Relaxed);
            Ok(dropped)
        })
    }

    /// Re-admit an evicted tenant from its snapshot (same governor path
    /// as a fresh admission; may land in a different slot).
    pub fn restore(&self, snap: TenantSnapshot) -> Result<TenantId> {
        ensure!(
            snap.cfg.l == self.cfg.l && snap.cfg.int8_frozen == self.cfg.int8_frozen,
            "snapshot split/mode does not match this fleet"
        );
        let needed = self.tenant_overhead + snap.replay_bytes();
        let mut admin = self.admin.lock().unwrap();
        // slot check before irreversible relief (same as admission)
        let id = self.free_slot(&admin)?;
        self.make_room(&mut admin, needed, "tenant restore", self.admit_mode())?;
        // the fresh slot's submit counter must clear every sequence the
        // snapshot knows about (parked events included), or future
        // stamps would collide with the captured reorder buffer
        let seq = snap.seq_ceiling();
        let tenant = Tenant::restore(id, &*self.be, snap)?;
        let bytes = self.tenant_overhead + tenant.replay_bytes();
        *self.slots[id].tenant.lock().unwrap() = Some(tenant);
        self.slots[id].submit_seq.store(seq, Ordering::Relaxed);
        self.slots[id]
            .last_active
            .store(self.clock.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
        self.commit_gov(&mut admin, GovernorAction::Restore { tenant: id, bytes });
        Ok(id)
    }

    // ---- the serving loop ------------------------------------------------

    /// Stamp an event with its per-tenant sequence number + logical clock
    /// tick. MUST be called in the intended per-tenant order (the
    /// single submitting thread of `run`, or any caller that serializes
    /// per tenant).
    fn stamp(&self, ev: &mut FleetEvent) -> Result<()> {
        ensure!(ev.tenant < self.slots.len(), "unknown tenant {}", ev.tenant);
        ensure!(
            !ev.labels.is_empty() && ev.images.len() == ev.labels.len() * self.image_elems,
            "event for tenant {}: ragged images",
            ev.tenant
        );
        ev.seq = self.slots[ev.tenant].submit_seq.fetch_add(1, Ordering::Relaxed);
        self.slots[ev.tenant]
            .last_active
            .store(self.clock.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
        ev.submitted = Some(Instant::now());
        Ok(())
    }

    /// Stage B: hand one event's latents to its tenant, in sequence. A
    /// spilled tenant is transparently readmitted from the cold tier
    /// first (the lazy-restore path) — its slot kept its submit counter
    /// and the snapshot kept `next_seq`, so sequence parking works
    /// across the spill exactly as if the tenant had never left RAM.
    fn dispatch(&self, ev: FleetEvent, latents: Vec<f32>) -> Result<()> {
        let FleetEvent { tenant, labels, seq, submitted, .. } = ev;
        let mut payload = Some((latents, labels));
        loop {
            {
                let mut guard = self.slots[tenant].tenant.lock().unwrap();
                if let Some(t) = guard.as_mut() {
                    if seq < t.next_seq() {
                        // only reachable after a degrade rebuilt the
                        // tenant past this stamp: the event belongs to
                        // the lost trajectory — drop it, count it
                        drop(guard);
                        self.events_dropped.fetch_add(1, Ordering::Relaxed);
                        return Ok(());
                    }
                    let (lat, lab) = payload.take().expect("dispatch applies an event once");
                    let applied = t.accept(&*self.be, seq, lat, lab, submitted)?;
                    drop(guard);
                    let n_applied = applied.len() as u64;
                    self.events_done.fetch_add(n_applied, Ordering::Relaxed);
                    if !applied.is_empty() {
                        let now = Instant::now();
                        let tm = &self.cfg.telemetry;
                        let mut max_ns = 0u64;
                        let mut lat = self.latency_ns.lock().unwrap();
                        // one sample per applied event, each charged from
                        // its OWN submit stamp (parked events waited
                        // longer — and a lazy restore's decode cost lands
                        // on the event that triggered it)
                        for stamp in applied.into_iter().flatten() {
                            let ns = now.duration_since(stamp).as_nanos() as u64;
                            lat.push(ns as f64);
                            tm.hist_ns(TmPath::Dispatch, ns);
                            max_ns = max_ns.max(ns);
                        }
                        drop(lat);
                        // one complete event per dispatch, back-dated
                        // over the longest-waiting applied stamp
                        tm.event_ns(
                            EventKind::Dispatch,
                            seq,
                            tenant as u32,
                            LANE_HIGH,
                            max_ns,
                            n_applied,
                            seq,
                        );
                        tm.counter_add(Counter::Dispatches, 1);
                    }
                    return Ok(());
                }
            }
            match self.try_restore_spilled(tenant, true) {
                Ok(true) => {} // resident now (restored, raced, or degraded): retry the lock
                Ok(false) => {
                    // tenant evicted with events in flight: drop, count
                    self.events_dropped.fetch_add(1, Ordering::Relaxed);
                    return Ok(());
                }
                Err(e) => {
                    // the restore path itself failed (exhausted I/O
                    // retries, or relief could not make room). SURVIVAL
                    // over completeness: drop this event and leave the
                    // tenant cold — it is still registered and
                    // accounted, and a later event (or rebalance) will
                    // retry. Erroring here would abort the whole run.
                    eprintln!(
                        "[fleet] tenant {tenant}: lazy restore failed ({e:#}); \
                         event dropped, tenant stays in the cold tier"
                    );
                    self.events_dropped.fetch_add(1, Ordering::Relaxed);
                    self.note_pressure();
                    return Ok(());
                }
            }
        }
    }

    fn worker_loop(&self, queue: &Bounded<FleetEvent>) -> Result<()> {
        let mut coal = FrozenCoalescer::new(self.image_elems, self.latent_elems);
        loop {
            // chaos hook: a scheduled slow-worker stall (no-op when
            // faults are disabled)
            if let Some(d) = self.cfg.faults.stall() {
                std::thread::sleep(d);
            }
            let (batch, depth) = queue.pop_many_observed(self.cfg.coalesce);
            if batch.is_empty() {
                return Ok(());
            }
            let tm = &self.cfg.telemetry;
            tm.gauge_max(Gauge::QueueDepthPeak, depth as u64);
            tm.counter_add(Counter::CoalescedEvents, batch.len() as u64);
            // stage A: ONE shared-backbone call for the whole batch,
            // whatever mix of tenants it contains
            let mut batch_sp = tm.span(EventKind::Coalesce).lane(LANE_HIGH);
            coal.clear();
            for ev in &batch {
                coal.push(&ev.images);
            }
            batch_sp.set_payload(batch.len() as u64, coal.rows() as u64);
            coal.run(&*self.be, self.cfg.l, self.cfg.int8_frozen)?;
            self.frozen_calls.fetch_add(1, Ordering::Relaxed);
            self.frozen_rows.fetch_add(coal.rows() as u64, Ordering::Relaxed);
            drop(batch_sp);
            // stage B: per-row tenant dispatch on the adaptive stage
            for (i, ev) in batch.into_iter().enumerate() {
                let latents = coal.latents(i).to_vec();
                self.dispatch(ev, latents)?;
            }
            // chaos hook: a scheduled memory-budget shock once enough
            // events have been applied fleet-wide. Survival, not abort:
            // an infeasible shrink is logged and skipped.
            if let Some(factor) =
                self.cfg.faults.take_shock(self.events_done.load(Ordering::Relaxed))
            {
                if let Err(e) = self.shock_budget_factor(factor) {
                    eprintln!("[fleet] budget shock could not be applied: {e:#}");
                    self.note_pressure();
                }
            }
        }
    }

    /// Drive a full event stream through the fleet: `workers`
    /// pool-resident tasks (high lane of the shared persistent
    /// [`crate::exec::ExecPool`] — no per-run thread spawns) drain the
    /// bounded ingress queue while this thread submits. Returns the
    /// throughput/latency report. Events for one tenant are applied in
    /// submission order; tenants progress independently.
    ///
    /// One serving run at a time per server (the latency/coalescing
    /// counters are per-run); admissions, evictions, inference and
    /// evaluation may all proceed concurrently with a run.
    ///
    /// If a run errors out, stamped-but-undelivered events leave the
    /// affected tenants with sequence gaps (future events would park
    /// forever behind the missing seq); call
    /// [`FleetServer::resync_sequences`] per tenant to recover.
    pub fn run(
        &self,
        events: impl IntoIterator<Item = FleetEvent>,
        workers: usize,
    ) -> Result<FleetReport> {
        let workers = workers.max(1);
        // kernel- and pool-level spans record through the process-global
        // slot; point it at this run's sink for the duration. Installed
        // only when enabled, so a plain run never swaps out a slot some
        // other component installed.
        let _tm_guard = self.install_telemetry();
        let queue = Bounded::new(self.cfg.queue_depth);
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let base = self.run_base();
        let shed_wait = self.shed_wait();
        // consecutive sheds per tenant -> exponential retry-after hints
        let mut shed_streak: BTreeMap<TenantId, u32> = BTreeMap::new();
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..workers)
                .map(|_| {
                    let queue = &queue;
                    let first_err = &first_err;
                    Box::new(move || {
                        if let Err(e) = self.worker_loop(queue) {
                            let mut slot = first_err.lock().unwrap();
                            if slot.is_none() {
                                *slot = Some(e);
                            }
                            queue.close(); // fail fast: stop the whole run
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            let serving = crate::exec::global().submit_group(crate::exec::Lane::High, jobs);
            // created AFTER the handle, so it drops FIRST: if the events
            // iterator panics mid-feed, the queue still closes and the
            // handle's join cannot deadlock on parked workers
            struct CloseOnDrop<'q>(&'q Bounded<FleetEvent>);
            impl Drop for CloseOnDrop<'_> {
                fn drop(&mut self) {
                    self.0.close();
                }
            }
            let _close_guard = CloseOnDrop(&queue);
            for mut ev in events {
                if let Some(wait) = shed_wait {
                    // admission control runs BEFORE stamping: a shed
                    // event never consumes a sequence number, so it
                    // leaves no gap for later events to park behind
                    if !queue.wait_space(wait) {
                        self.shed_event(ev.tenant, &mut shed_streak);
                        continue;
                    }
                    shed_streak.remove(&ev.tenant);
                }
                if let Err(e) = self.stamp(&mut ev) {
                    let mut slot = first_err.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                    break;
                }
                if !queue.push(ev) {
                    break; // closed by a failing worker
                }
            }
            queue.close();
            serving.wait();
        }
        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        Ok(self.finish_report(&base))
    }

    /// Install this server's telemetry sink process-globally (kernel-
    /// and pool-level spans record through the global slot). Installed
    /// only when enabled, so a plain run never swaps out a slot some
    /// other component installed. Hold the guard for the serving
    /// duration; [`FleetServer::run`] does this itself, network serving
    /// ([`crate::net::server`]) holds it across the whole accept loop.
    pub fn install_telemetry(&self) -> Option<crate::telemetry::InstallGuard> {
        if self.cfg.telemetry.is_enabled() {
            Some(crate::telemetry::install(&self.cfg.telemetry))
        } else {
            None
        }
    }

    /// The configured shed deadline, `None` under block admission.
    fn shed_wait(&self) -> Option<Duration> {
        match self.cfg.admission {
            Admission::Block => None,
            Admission::Shed { max_wait_ms } => Some(Duration::from_millis(max_wait_ms)),
        }
    }

    /// Capture counter baselines (and reset the latency samples) at the
    /// start of a serving run/session; the report is the delta.
    fn run_base(&self) -> RunBase {
        self.latency_ns.lock().unwrap().clear();
        RunBase {
            done0: self.events_done.load(Ordering::Relaxed),
            calls0: self.frozen_calls.load(Ordering::Relaxed),
            rows0: self.frozen_rows.load(Ordering::Relaxed),
            drop0: self.events_dropped.load(Ordering::Relaxed),
            lazy0: self.lazy_restores.load(Ordering::Relaxed),
            shed0: self.shed.load(Ordering::Relaxed),
            retries0: self.io_retries.load(Ordering::Relaxed),
            degrades0: self.degrades.load(Ordering::Relaxed),
            t0: Instant::now(),
        }
    }

    /// Record one shed: bump the tenant's consecutive-shed streak,
    /// derive the exponential retry-after quote, and mirror it into the
    /// pressure ladder, telemetry, and the rejection drain. Returns the
    /// quote — admission replies carry it back to the client, which
    /// backs off exactly this long before resubmitting.
    fn shed_event(&self, tenant: TenantId, shed_streak: &mut BTreeMap<TenantId, u32>) -> u64 {
        let streak = shed_streak.entry(tenant).or_insert(0);
        let retry_after_ms = 1u64 << (*streak).min(6);
        *streak += 1;
        let shed_n = self.shed.fetch_add(1, Ordering::Relaxed) + 1;
        self.note_pressure();
        self.cfg.telemetry.event_ns(
            EventKind::Shed,
            shed_n,
            tenant as u32,
            LANE_NONE,
            0,
            retry_after_ms,
            0,
        );
        self.rejections
            .lock()
            .unwrap()
            .push(Rejected::Overloaded { tenant, retry_after_ms });
        retry_after_ms
    }

    /// Assemble the serving report as the delta against `base`,
    /// folding authoritative totals into the telemetry digest.
    fn finish_report(&self, base: &RunBase) -> FleetReport {
        let wall = base.t0.elapsed().as_secs_f64();
        let events = self.events_done.load(Ordering::Relaxed) - base.done0;
        let frozen_calls = self.frozen_calls.load(Ordering::Relaxed) - base.calls0;
        let frozen_rows = self.frozen_rows.load(Ordering::Relaxed) - base.rows0;
        let mut lat = self.latency_ns.lock().unwrap();
        let robustness = RobustnessSummary {
            shed: self.shed.load(Ordering::Relaxed) - base.shed0,
            io_retries: self.io_retries.load(Ordering::Relaxed) - base.retries0,
            degrades: self.degrades.load(Ordering::Relaxed) - base.degrades0,
        };
        let lazy_restores = self.lazy_restores.load(Ordering::Relaxed) - base.lazy0;
        let tm = &self.cfg.telemetry;
        // authoritative totals over the live approximations, then
        // freeze the digest into the report
        tm.fold_robustness(&robustness);
        tm.counter_set(Counter::LazyRestores, lazy_restores);
        FleetReport {
            events,
            dropped: self.events_dropped.load(Ordering::Relaxed) - base.drop0,
            wall_s: wall,
            events_per_sec: if wall > 0.0 { events as f64 / wall } else { 0.0 },
            latency: LatencySummary::from_ns(&mut lat),
            frozen_calls,
            frozen_rows,
            mean_coalesce: if frozen_calls > 0 {
                events as f64 / frozen_calls as f64
            } else {
                0.0
            },
            lazy_restores,
            robustness,
            telemetry: tm.report(),
        }
    }

    /// Has tenant `id` applied every event stamped for it? (No events in
    /// flight in the ingress queue and no parked early arrivals.) The
    /// quiesce gate [`FleetServer::evict`] requires — network drains
    /// poll it before migrating a tenant off this host. Never restores a
    /// cold tenant: a spilled tenant answers from its snapshot file.
    pub fn quiesced(&self, id: TenantId) -> Result<bool> {
        ensure!(id < self.slots.len(), "unknown tenant {id}");
        let stamped = self.slots[id].submit_seq.load(Ordering::Relaxed);
        {
            let guard = self.slots[id].tenant.lock().unwrap();
            if let Some(t) = guard.as_ref() {
                return Ok(stamped == t.next_seq());
            }
        }
        let path = {
            let admin = self.admin.lock().unwrap();
            match admin.spilled.get(&id) {
                Some(rec) => rec.path.clone(),
                None => bail!("tenant {id} is neither resident nor spilled"),
            }
        };
        // cold tenant: the snapshot records the applied sequence. Decoded
        // outside the admin lock; a racing restore just means the next
        // poll takes the resident path.
        let snap = snapshot::read_file(&path)?;
        Ok(stamped == snap.next_seq && snap.parked.is_empty())
    }

    /// Per-tenant activity for the shard rebalancer: `(id, last_active
    /// tick, resident?)` for every live tenant, coldest = smallest tick.
    pub fn tenant_heat(&self) -> Vec<(TenantId, u64, bool)> {
        let admin = self.admin.lock().unwrap();
        let mut out = Vec::new();
        for (id, slot) in self.slots.iter().enumerate() {
            if admin.spilled.contains_key(&id) {
                out.push((id, slot.last_active.load(Ordering::Relaxed), false));
            } else if slot.tenant.lock().unwrap().is_some() {
                out.push((id, slot.last_active.load(Ordering::Relaxed), true));
            }
        }
        out
    }

    /// Start an open-ended serving session: `workers` pool-resident
    /// tasks drain the bounded ingress queue exactly as in
    /// [`FleetServer::run`], but submission is a method
    /// ([`ServingSession::submit`]) instead of an iterator — the shape a
    /// network ingress needs, where events arrive from connection
    /// handlers until a drain/shutdown frame ends the session.
    ///
    /// `run` and a session share the same worker loop, stamping,
    /// admission control and report assembly, so a single-shard session
    /// is outcome-identical to `run` over the same per-tenant event
    /// order. One serving run OR session at a time per server.
    ///
    /// Unlike `run`, a session does NOT install the telemetry sink
    /// process-globally (the guard is not `Sync`, and sessions are
    /// shared across connection threads) — callers that want kernel- and
    /// pool-level spans hold [`FleetServer::install_telemetry`] for the
    /// session's lifetime.
    pub fn start_session(self: &Arc<Self>, workers: usize) -> ServingSession {
        let workers = workers.max(1);
        let queue = Arc::new(Bounded::new(self.cfg.queue_depth));
        let first_err: Arc<Mutex<Option<anyhow::Error>>> = Arc::new(Mutex::new(None));
        let base = self.run_base();
        let shed_wait = self.shed_wait();
        let jobs: Vec<Box<dyn FnOnce() + Send + 'static>> = (0..workers)
            .map(|_| {
                let srv = self.clone();
                let queue = queue.clone();
                let first_err = first_err.clone();
                Box::new(move || {
                    if let Err(e) = srv.worker_loop(&queue) {
                        let mut slot = first_err.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(e);
                        }
                        queue.close(); // fail fast: stop the whole session
                    }
                }) as Box<dyn FnOnce() + Send + 'static>
            })
            .collect();
        let handle = crate::exec::global().submit_group(crate::exec::Lane::High, jobs);
        ServingSession {
            server: self.clone(),
            queue,
            handle: Some(handle),
            first_err,
            submit_state: Mutex::new(BTreeMap::new()),
            shed_wait,
            base,
        }
    }

    // ---- evaluation + batched inference ---------------------------------

    /// Fleet-shared test latents: the frozen stage is identical for every
    /// tenant, so the test split is embedded ONCE per server (mirroring
    /// the single-session `EvalLatentCache`, but across tenants).
    fn test_latents(&self, ds: &crate::runtime::Dataset) -> Result<Arc<(Vec<f32>, Vec<i32>)>> {
        let mut cache = self.test_cache.lock().unwrap();
        if let Some(hit) = cache.as_ref() {
            return Ok(hit.clone());
        }
        let m = self.be.manifest();
        let n = ds.n_test();
        let b = m.batch_eval;
        let img = self.image_elems;
        let le = self.latent_elems;
        let mut images = vec![0f32; b * img];
        let mut lat_chunk = vec![0f32; b * le];
        let mut latents = vec![0f32; n * le];
        let mut labels = vec![0i32; n];
        let mut start = 0;
        while start < n {
            let count = (n - start).min(b);
            for slot in 0..b {
                // pad tail by repeating the last real image (same scheme
                // as Session::latents_for — rows are per-row exact, so
                // padding never leaks into real outputs)
                let idx = start + slot.min(count - 1);
                ds.test_image_into(idx, &mut images[slot * img..(slot + 1) * img]);
            }
            self.be
                .frozen_forward(self.cfg.l, self.cfg.int8_frozen, true, &images, &mut lat_chunk)?;
            for slot in 0..count {
                let idx = start + slot;
                latents[idx * le..(idx + 1) * le]
                    .copy_from_slice(&lat_chunk[slot * le..(slot + 1) * le]);
                labels[idx] = ds.test_labels[idx];
            }
            start += count;
        }
        let entry = Arc::new((latents, labels));
        *cache = Some(entry.clone());
        Ok(entry)
    }

    /// Held-out accuracy of one tenant over the shared test embedding
    /// (lazily restoring the tenant from the cold tier if spilled).
    pub fn evaluate_tenant(&self, ds: &crate::runtime::Dataset, id: TenantId) -> Result<f64> {
        let cached = self.test_latents(ds)?;
        self.with_resident(id, |t| t.evaluate(&*self.be, &cached.0, &cached.1))
    }

    /// Full test-set eval for many tenants, OFF the serving path: the
    /// shared test embedding is built inline once (so the expensive
    /// frozen sweep never races a concurrent run for the cache lock),
    /// then one LOW-lane pool task per tenant scores it. Low-lane tasks
    /// never occupy the whole pool — at least one worker always stays
    /// free for high-lane serving work — so a full eval sweep cannot
    /// stall event dispatch (pinned by `eval_sweep_does_not_block_
    /// dispatch` in `rust/tests/fleet.rs`).
    ///
    /// Per-tenant accuracies are bit-identical to sequential
    /// [`FleetServer::evaluate_tenant`] calls on a quiesced server; run
    /// concurrently with serving, each tenant is scored at whatever
    /// training step its slot lock is won (same semantics as calling
    /// `evaluate_tenant` mid-run today).
    pub fn evaluate_tenants_async<'s>(
        &'s self,
        ds: &crate::runtime::Dataset,
        ids: &[TenantId],
    ) -> Result<EvalHandle<'s>> {
        let cached = self.test_latents(ds)?;
        let jobs: Vec<Box<dyn FnOnce() -> Result<f64> + Send + 's>> = ids
            .iter()
            .map(|&id| {
                let cached = cached.clone();
                let tm = self.cfg.telemetry.clone();
                Box::new(move || {
                    let _sp = tm
                        .owned_span(EventKind::EvalSweep)
                        .tenant(id as u32)
                        .lane(LANE_LOW)
                        .hist(TmPath::Eval)
                        .counter(Counter::EvalSweeps);
                    self.with_resident(id, |t| t.evaluate(&*self.be, &cached.0, &cached.1))
                }) as Box<dyn FnOnce() -> Result<f64> + Send + 's>
            })
            .collect();
        Ok(EvalHandle {
            inner: crate::exec::global().submit_group(crate::exec::Lane::Low, jobs),
        })
    }

    /// Strided subset of the shared test embedding (every
    /// [`EVAL_SAMPLE_STRIDE`]-th example), built once per server. The
    /// middle rung of the degradation ladder: ~1/stride the eval cost,
    /// deterministic subset, so a sampled accuracy is reproducible.
    fn sampled_test_latents(
        &self,
        ds: &crate::runtime::Dataset,
    ) -> Result<Arc<(Vec<f32>, Vec<i32>)>> {
        // lock order: sampled cache before the full-cache lock inside
        // test_latents — never the reverse anywhere, so no cycle
        let mut cache = self.sampled_cache.lock().unwrap();
        if let Some(hit) = cache.as_ref() {
            return Ok(hit.clone());
        }
        let full = self.test_latents(ds)?;
        let le = self.latent_elems;
        let n = full.1.len();
        let mut latents = Vec::with_capacity((n / EVAL_SAMPLE_STRIDE + 1) * le);
        let mut labels = Vec::with_capacity(n / EVAL_SAMPLE_STRIDE + 1);
        for idx in (0..n).step_by(EVAL_SAMPLE_STRIDE) {
            latents.extend_from_slice(&full.0[idx * le..(idx + 1) * le]);
            labels.push(full.1[idx]);
        }
        let entry = Arc::new((latents, labels));
        *cache = Some(entry.clone());
        Ok(entry)
    }

    /// Ladder-aware evaluation: answers at the server's current service
    /// level instead of always paying for a full pass.
    ///
    /// - [`ServiceLevel::Full`] — exact accuracy over the whole test split
    ///   (identical to [`FleetServer::evaluate_tenant`]);
    /// - [`ServiceLevel::Sampled`] — accuracy over the deterministic
    ///   1-in-[`EVAL_SAMPLE_STRIDE`] subset;
    /// - [`ServiceLevel::Deferred`] — no work now; the caller re-asks once
    ///   pressure clears ([`FleetServer::clear_pressure`]).
    pub fn evaluate_tenant_adaptive(
        &self,
        ds: &crate::runtime::Dataset,
        id: TenantId,
    ) -> Result<EvalOutcome> {
        match self.service_level() {
            ServiceLevel::Full => Ok(EvalOutcome::Full(self.evaluate_tenant(ds, id)?)),
            ServiceLevel::Sampled => {
                let cached = self.sampled_test_latents(ds)?;
                let acc =
                    self.with_resident(id, |t| t.evaluate(&*self.be, &cached.0, &cached.1))?;
                Ok(EvalOutcome::Sampled(acc))
            }
            ServiceLevel::Deferred => Ok(EvalOutcome::Deferred),
        }
    }

    /// Training metrics of one tenant. A spilled tenant's metrics come
    /// from the registry (stashed at spill time) — no disk read, no
    /// readmission.
    pub fn tenant_metrics(&self, id: TenantId) -> Result<super::tenant::TenantMetrics> {
        ensure!(id < self.slots.len(), "unknown tenant {id}");
        {
            let admin = self.admin.lock().unwrap();
            if let Some(rec) = admin.spilled.get(&id) {
                return Ok(rec.metrics);
            }
        }
        let guard = self.slots[id].tenant.lock().unwrap();
        Ok(guard.as_ref().ok_or_else(|| anyhow!("tenant {id} is not resident"))?.metrics)
    }

    /// Cross-session batched inference: ONE shared frozen call over every
    /// request's images, then per-row tenant dispatch on the adaptive
    /// stage. At the head-only split (`l` = number of conv layers) the
    /// dispatch itself is a single grouped engine call
    /// ([`Engine::matmul_fw_grouped_into`]) spanning all tenants; deeper
    /// adaptive stages fall back to one `adaptive_eval` per tenant group.
    /// Returns per-request logits `[rows, num_classes]` in request order.
    ///
    /// [`Engine::matmul_fw_grouped_into`]: crate::kernels::Engine::matmul_fw_grouped_into
    pub fn infer_batch(&self, reqs: &[InferRequest<'_>]) -> Result<Vec<Vec<f32>>> {
        let m = self.be.manifest();
        let ncls = m.num_classes;
        let le = self.latent_elems;
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let mut rows_of = Vec::with_capacity(reqs.len());
        let mut total_rows = 0usize;
        for (i, r) in reqs.iter().enumerate() {
            ensure!(r.tenant < self.slots.len(), "unknown tenant {}", r.tenant);
            ensure!(
                !r.images.is_empty() && r.images.len() % self.image_elems == 0,
                "infer request {i}: ragged images"
            );
            let rows = r.images.len() / self.image_elems;
            rows_of.push(rows);
            total_rows += rows;
        }
        // stage A: one coalesced frozen forward across all requests
        let mut images = Vec::with_capacity(total_rows * self.image_elems);
        for r in reqs {
            images.extend_from_slice(r.images);
        }
        let mut latents = vec![0f32; total_rows * le];
        self.be
            .frozen_forward(self.cfg.l, self.cfg.int8_frozen, false, &images, &mut latents)?;

        // sort requests by tenant so each tenant's rows are contiguous
        let mut req_order: Vec<usize> = (0..reqs.len()).collect();
        req_order.sort_by_key(|&i| (reqs[i].tenant, i));
        let mut sorted_latents = vec![0f32; total_rows * le];
        let mut req_start = vec![0usize; reqs.len()]; // row start in original order
        let mut acc = 0;
        for (i, &rows) in rows_of.iter().enumerate() {
            req_start[i] = acc;
            acc += rows;
        }
        let mut sorted_pos = vec![0usize; reqs.len()]; // row start in sorted order
        let mut cursor = 0;
        for &i in &req_order {
            sorted_pos[i] = cursor;
            let rows = rows_of[i];
            sorted_latents[cursor * le..(cursor + rows) * le]
                .copy_from_slice(&latents[req_start[i] * le..(req_start[i] + rows) * le]);
            cursor += rows;
        }

        // per-tenant contiguous groups over the sorted rows
        let mut groups: Vec<(TenantId, usize, usize)> = Vec::new(); // (tenant, row0, rows)
        for &i in &req_order {
            let t = reqs[i].tenant;
            match groups.last_mut() {
                Some(g) if g.0 == t => g.2 += rows_of[i],
                _ => groups.push((t, sorted_pos[i], rows_of[i])),
            }
        }

        // lock the tenants in ascending id order (the fleet's multi-lock
        // order); req_order sorted by tenant gives us exactly that. A
        // spilled target is lazily restored first — and because the
        // admin lock must never be taken while holding a tenant guard,
        // a target that goes cold again between the restore and its
        // lock (a competing lazy restore spilled it) drops every guard
        // and retries, like the dispatch path does.
        let guards = loop {
            for &(t, _, _) in &groups {
                ensure!(self.try_restore_spilled(t, false)?, "tenant {t} is not resident");
            }
            let mut acquired = Vec::with_capacity(groups.len());
            for &(t, _, _) in &groups {
                let g = self.slots[t].tenant.lock().unwrap();
                if g.is_none() {
                    acquired.clear(); // went cold again: release and retry
                    break;
                }
                acquired.push(g);
            }
            if acquired.len() == groups.len() {
                break acquired;
            }
        };

        let n_conv = self.net.layers.len() - 1;
        let mut sorted_logits = vec![0f32; total_rows * ncls];
        if self.cfg.l == n_conv {
            // head-only adaptive stage: one grouped engine call for the
            // whole fleet batch — params are [b (ncls)], [w (feat,ncls)]
            let engine = crate::kernels::default_engine();
            let weights: Vec<&[f32]> = guards
                .iter()
                .map(|g| g.as_ref().unwrap().params.tensor(1).data.as_slice())
                .collect();
            let group_spec: Vec<(usize, &[f32])> = groups
                .iter()
                .zip(&weights)
                .map(|(&(_, _, rows), &w)| (rows, w))
                .collect();
            engine.matmul_fw_grouped_into(
                &sorted_latents,
                &group_spec,
                le,
                ncls,
                &mut sorted_logits,
            );
            for (gi, &(_, row0, rows)) in groups.iter().enumerate() {
                let bias = &guards[gi].as_ref().unwrap().params.tensor(0).data;
                for r in row0..row0 + rows {
                    for (c, v) in sorted_logits[r * ncls..(r + 1) * ncls].iter_mut().enumerate() {
                        *v += bias[c];
                    }
                }
            }
        } else {
            // deeper adaptive stages: one backend call per tenant group
            for (gi, &(_, row0, rows)) in groups.iter().enumerate() {
                let t = guards[gi].as_ref().unwrap();
                self.be.adaptive_eval(
                    self.cfg.l,
                    &t.params,
                    &sorted_latents[row0 * le..(row0 + rows) * le],
                    &mut sorted_logits[row0 * ncls..(row0 + rows) * ncls],
                )?;
            }
        }
        drop(guards);

        // scatter back to request order
        let mut out = Vec::with_capacity(reqs.len());
        for i in 0..reqs.len() {
            let rows = rows_of[i];
            let p = sorted_pos[i];
            out.push(sorted_logits[p * ncls..(p + rows) * ncls].to_vec());
        }
        Ok(out)
    }
}

/// Counter baselines captured when a serving run/session begins; the
/// final [`FleetReport`] is the delta against these.
struct RunBase {
    done0: u64,
    calls0: u64,
    rows0: u64,
    drop0: u64,
    lazy0: u64,
    shed0: u64,
    retries0: u64,
    degrades0: u64,
    t0: Instant,
}

/// Outcome of one [`ServingSession::submit`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Submitted {
    /// Stamped and enqueued; workers will apply it in sequence.
    Enqueued,
    /// Shed by admission control before stamping (no sequence gap). The
    /// caller should resubmit after exactly `retry_after_ms` — the quote
    /// doubles per consecutive shed and resets on the next admit.
    Shed { retry_after_ms: u64 },
}

/// An open-ended serving run: the same pool workers, bounded queue,
/// admission control and reporting as [`FleetServer::run`], driven by
/// [`ServingSession::submit`] calls instead of an event iterator.
///
/// This is the seam the network ingress ([`crate::net::server`]) feeds:
/// connection handler threads submit as frames arrive, and the session
/// ends (draining workers and assembling the [`FleetReport`]) only when
/// [`ServingSession::finish`] is called.
///
/// Submission is serialized by an internal lock, so per-tenant sequence
/// stamping sees one submitter — the same ordering discipline `run`'s
/// single feeding thread provides. Events for one tenant must still
/// arrive in their intended order (one connection per tenant upholds
/// this in the sharded fleet).
pub struct ServingSession {
    server: Arc<FleetServer>,
    queue: Arc<Bounded<FleetEvent>>,
    handle: Option<crate::exec::GroupHandle<'static, ()>>,
    first_err: Arc<Mutex<Option<anyhow::Error>>>,
    /// consecutive-shed streaks per tenant; the lock doubles as the
    /// submission serializer
    submit_state: Mutex<BTreeMap<TenantId, u32>>,
    shed_wait: Option<Duration>,
    base: RunBase,
}

impl ServingSession {
    /// The server this session serves.
    pub fn server(&self) -> &Arc<FleetServer> {
        &self.server
    }

    /// Submit one event: admission control (shed with a retry-after
    /// quote under [`Admission::Shed`], block under [`Admission::Block`])
    /// then stamp + enqueue. Errors only when the session is already
    /// closed (a worker failed — the cause surfaces at `finish`).
    pub fn submit(&self, mut ev: FleetEvent) -> Result<Submitted> {
        let mut streaks = self.submit_state.lock().unwrap();
        if let Some(wait) = self.shed_wait {
            if !self.queue.wait_space(wait) {
                let retry_after_ms = self.server.shed_event(ev.tenant, &mut streaks);
                return Ok(Submitted::Shed { retry_after_ms });
            }
            streaks.remove(&ev.tenant);
        }
        self.server.stamp(&mut ev)?;
        ensure!(
            self.queue.push(ev),
            "serving session closed (a worker failed; see finish())"
        );
        Ok(Submitted::Enqueued)
    }

    /// Convenience: build and submit one event from raw images.
    pub fn submit_event(
        &self,
        tenant: TenantId,
        images: Vec<f32>,
        labels: Vec<i32>,
    ) -> Result<Submitted> {
        self.submit(FleetEvent::new(tenant, images, labels))
    }

    /// Close the queue, join the workers, and assemble the report —
    /// `run`'s epilogue. The first worker error (if any) wins.
    pub fn finish(mut self) -> Result<FleetReport> {
        self.queue.close();
        if let Some(handle) = self.handle.take() {
            handle.wait();
        }
        if let Some(e) = self.first_err.lock().unwrap().take() {
            return Err(e);
        }
        Ok(self.server.finish_report(&self.base))
    }
}

impl Drop for ServingSession {
    fn drop(&mut self) {
        // a dropped (not finished) session still closes the queue so the
        // group handle's Drop join cannot deadlock on parked workers
        self.queue.close();
    }
}
