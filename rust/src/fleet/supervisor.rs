//! [`ShardSupervisor`]: spawn, watch and restart shard processes.
//!
//! The missing piece between "a fleet of shard processes" and "a fleet
//! that survives one of them dying": the supervisor spawns each
//! `tinycl shard` child, waits for its machine-readable
//! `shard I listening on ADDR` line, publishes the address list
//! atomically to an `--addrs-file` (tmp + rename, the snapshot
//! module's publish discipline), and then heartbeats every shard with
//! protocol-level Pings on a fixed cadence.
//!
//! Failure handling is restart-based and deliberately simple:
//!
//! - a child that *exits cleanly* (status 0 — the Shutdown frame's
//!   path) is finished, not failed; the supervisor lets it go and
//!   returns once every shard finished;
//! - a child that dies any other way (crash, kill, scripted
//!   [`FaultPlan::with_shard_crash`] exit) or misses
//!   `max_misses` consecutive pings is killed, reaped and respawned
//!   with the SAME shard index and the SAME spill directory — so the
//!   replacement adopts the spill tier's recovery scan and any
//!   mid-migration `.tomb` files exactly where the dead process left
//!   them;
//! - every restart rewrites the addrs file (the replacement binds a
//!   fresh ephemeral port); clients notice `ShardDown`, re-read the
//!   file, and `re_resolve`.
//!
//! MTTR is measured per restart: detection (failed ping or observed
//! exit) to the replacement's first successful ping.
//!
//! [`FaultPlan::with_shard_crash`]: crate::fleet::faults::FaultPlan::with_shard_crash

use std::io::{BufRead, BufReader};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::net::frame::{client_handshake, recv_reply, send_request, Reply, Request};

/// Everything needed to spawn and police one fleet of shard processes.
#[derive(Clone, Debug)]
pub struct SupervisorConfig {
    /// The `tinycl` binary to spawn (`std::env::current_exe()` for the
    /// CLI, `env!("CARGO_BIN_EXE_tinycl")` in integration tests).
    pub binary: PathBuf,
    /// How many shards to run.
    pub shards: usize,
    /// Worker threads per shard.
    pub workers: usize,
    /// Per-shard spill dirs live at `spill_root/shard<i>` — stable
    /// across restarts, which is what makes recovery + tombstone
    /// adoption work.
    pub spill_root: PathBuf,
    /// The address list, rewritten atomically on every (re)bind.
    pub addrs_file: PathBuf,
    /// Ping cadence.
    pub heartbeat: Duration,
    /// Per-ping connect/read deadline.
    pub ping_timeout: Duration,
    /// Consecutive missed pings before a shard is declared dead.
    pub max_misses: u32,
    /// Scripted crash for the chaos drill: `(shard index, frames)` —
    /// applied to the FIRST spawn only (the replacement must live).
    pub crash: Option<(usize, u64)>,
    /// Extra args appended to every `tinycl shard` invocation.
    pub shard_args: Vec<String>,
}

impl SupervisorConfig {
    pub fn new(binary: PathBuf, shards: usize, spill_root: PathBuf, addrs_file: PathBuf) -> Self {
        SupervisorConfig {
            binary,
            shards,
            workers: 2,
            spill_root,
            addrs_file,
            heartbeat: Duration::from_millis(100),
            ping_timeout: Duration::from_millis(500),
            max_misses: 3,
            crash: None,
            shard_args: Vec::new(),
        }
    }
}

/// What one supervised serve looked like.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SupervisorReport {
    /// Shards restarted after a crash or heartbeat loss.
    pub restarts: u64,
    /// Detection → replacement-answers-pings, one entry per restart.
    pub mttr_ms: Vec<u64>,
}

struct ShardProc {
    child: Child,
    addr: String,
    misses: u32,
    /// exited with status 0 — done, not dead
    finished: bool,
    restarts: u32,
}

/// One supervised fleet of shard processes.
pub struct ShardSupervisor {
    cfg: SupervisorConfig,
    procs: Vec<ShardProc>,
    report: SupervisorReport,
}

impl ShardSupervisor {
    /// Spawn every shard, wait for each listening line, publish the
    /// addrs file.
    pub fn start(cfg: SupervisorConfig) -> Result<ShardSupervisor> {
        anyhow::ensure!(cfg.shards >= 1, "supervisor needs at least one shard");
        let mut procs = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let first_spawn = true;
            let (child, addr) = spawn_shard(&cfg, i, first_spawn)?;
            procs.push(ShardProc { child, addr, misses: 0, finished: false, restarts: 0 });
        }
        let sup = ShardSupervisor { cfg, procs, report: SupervisorReport::default() };
        sup.publish_addrs()?;
        Ok(sup)
    }

    /// The current address list, shard-index order.
    pub fn addresses(&self) -> Vec<String> {
        self.procs.iter().map(|p| p.addr.clone()).collect()
    }

    /// Restart counts per shard.
    pub fn restarts(&self) -> Vec<u32> {
        self.procs.iter().map(|p| p.restarts).collect()
    }

    /// Atomically rewrite the addrs file (tmp + rename).
    fn publish_addrs(&self) -> Result<()> {
        let body = self
            .procs
            .iter()
            .map(|p| p.addr.as_str())
            .collect::<Vec<_>>()
            .join("\n")
            + "\n";
        let tmp = self.cfg.addrs_file.with_extension("tmp");
        std::fs::write(&tmp, body).with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &self.cfg.addrs_file)
            .with_context(|| format!("publishing {}", self.cfg.addrs_file.display()))?;
        Ok(())
    }

    /// One supervision round: reap exits, ping the living, restart the
    /// dead. Returns the indices restarted this round.
    pub fn poll(&mut self) -> Result<Vec<usize>> {
        let mut restarted = Vec::new();
        for i in 0..self.procs.len() {
            if self.procs[i].finished {
                continue;
            }
            let dead = match self.procs[i].child.try_wait()? {
                Some(status) if status.success() => {
                    self.procs[i].finished = true;
                    continue;
                }
                Some(_) => true, // crashed or killed
                None => {
                    // alive as a process — but is it serving?
                    if probe(&self.procs[i].addr, self.cfg.ping_timeout) {
                        self.procs[i].misses = 0;
                        false
                    } else {
                        self.procs[i].misses += 1;
                        self.procs[i].misses >= self.cfg.max_misses
                    }
                }
            };
            if dead {
                self.restart(i)?;
                restarted.push(i);
            }
        }
        if !restarted.is_empty() {
            self.publish_addrs()?;
        }
        Ok(restarted)
    }

    /// Kill, reap and respawn shard `i` with the same index and spill
    /// dir; block until the replacement answers pings (that interval is
    /// the recorded MTTR).
    fn restart(&mut self, i: usize) -> Result<()> {
        let detected = Instant::now();
        let _ = self.procs[i].child.kill();
        let _ = self.procs[i].child.wait();
        // never re-arm a scripted crash: the replacement must live
        let (child, addr) = spawn_shard(&self.cfg, i, false)?;
        let deadline = detected + Duration::from_secs(120);
        while !probe(&addr, self.cfg.ping_timeout) {
            if Instant::now() > deadline {
                bail!("shard {i} replacement at {addr} never answered pings");
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        let mttr = detected.elapsed().as_millis() as u64;
        eprintln!("[supervisor] restarted shard {i} at {addr} (mttr {mttr} ms)");
        let restarts = self.procs[i].restarts + 1;
        self.procs[i] = ShardProc { child, addr, misses: 0, finished: false, restarts };
        self.report.restarts += 1;
        self.report.mttr_ms.push(mttr);
        Ok(())
    }

    /// Supervise until every shard finished cleanly (clients send the
    /// Shutdown frames; the supervisor polices everything in between).
    pub fn run(mut self) -> Result<SupervisorReport> {
        loop {
            if self.procs.iter().all(|p| p.finished) {
                return Ok(self.report);
            }
            self.poll()?;
            std::thread::sleep(self.cfg.heartbeat);
        }
    }

    /// Kill every child unconditionally (abort path; tests' cleanup).
    pub fn kill_all(&mut self) {
        for p in &mut self.procs {
            let _ = p.child.kill();
            let _ = p.child.wait();
        }
    }
}

/// Spawn one `tinycl shard`, wait for its listening line, hand back the
/// child plus its bound address. Remaining child stdout is drained by a
/// detached forwarder thread (a full pipe would wedge the shard).
fn spawn_shard(cfg: &SupervisorConfig, index: usize, first_spawn: bool) -> Result<(Child, String)> {
    let spill_dir = cfg.spill_root.join(format!("shard{index}"));
    std::fs::create_dir_all(&spill_dir)
        .with_context(|| format!("creating {}", spill_dir.display()))?;
    let mut cmd = Command::new(&cfg.binary);
    cmd.arg("shard")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .arg("--shard-index")
        .arg(index.to_string())
        .arg("--workers")
        .arg(cfg.workers.to_string())
        .arg("--spill-dir")
        .arg(&spill_dir)
        .args(&cfg.shard_args)
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit());
    if first_spawn {
        if let Some((crash_shard, frames)) = cfg.crash {
            if crash_shard == index {
                cmd.arg("--crash-after-frames").arg(frames.to_string());
            }
        }
    }
    let mut child = cmd.spawn().with_context(|| format!("spawning shard {index}"))?;
    let stdout = child.stdout.take().context("shard child has piped stdout")?;
    let mut lines = BufReader::new(stdout).lines();
    let needle = format!("shard {index} listening on ");
    let mut addr = None;
    for line in lines.by_ref() {
        let line = line.context("reading shard stdout")?;
        if let Some(a) = line.strip_prefix(&needle) {
            addr = Some(a.trim().to_string());
            break;
        }
        eprintln!("[shard {index}] {line}");
    }
    let Some(addr) = addr else {
        let _ = child.kill();
        let _ = child.wait();
        bail!("shard {index} exited before printing its listening line");
    };
    // keep draining so the child never blocks on a full pipe
    std::thread::spawn(move || {
        for line in lines.map_while(|l| l.ok()) {
            eprintln!("{line}");
        }
    });
    Ok((child, addr))
}

/// One protocol-level liveness probe: bounded connect, handshake, Ping.
fn probe(addr: &str, timeout: Duration) -> bool {
    let Ok(sock) = addr.parse::<SocketAddr>() else { return false };
    let Ok(mut s) = TcpStream::connect_timeout(&sock, timeout) else { return false };
    let _ = s.set_read_timeout(Some(timeout));
    let _ = s.set_write_timeout(Some(timeout));
    if client_handshake(&mut s).is_err() {
        return false;
    }
    if send_request(&mut s, &Request::Ping).is_err() {
        return false;
    }
    matches!(recv_reply(&mut s), Ok(Reply::Ok))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_refuses_cleanly_when_nothing_listens() {
        // a port from the ephemeral range with nothing bound: the probe
        // must report dead, not hang or panic
        assert!(!probe("127.0.0.1:1", Duration::from_millis(100)));
        assert!(!probe("not-an-addr", Duration::from_millis(100)));
    }

    #[test]
    fn config_defaults_are_sane() {
        let cfg = SupervisorConfig::new(
            PathBuf::from("/bin/true"),
            2,
            PathBuf::from("/tmp/x"),
            PathBuf::from("/tmp/x/addrs"),
        );
        assert_eq!(cfg.shards, 2);
        assert!(cfg.max_misses >= 1);
        assert!(cfg.ping_timeout > Duration::ZERO);
        assert!(cfg.crash.is_none());
    }
}
