//! The fleet's global memory governor (paper §III-B applied host-wide).
//!
//! The paper shows a single learner fits a 64 MB envelope because 8-bit
//! latent replays are ~lossless at 4x compression — and Ravaglia et al.'s
//! memory-latency-accuracy trade-off study (PAPERS.md) frames bit-width
//! as a *runtime knob*, not a compile-time constant. The governor takes
//! that literally: all tenants share one byte budget (default 64 MB), and
//! when admission would blow it, the **coldest** tenants pay first —
//! their replay buffers are demoted 8→7-bit in place (integer repack, no
//! dequantize round-trip), and past that their slot counts shrink. Every
//! action lands in an append-only log.
//!
//! The policy is a pure function of `(needed bytes, candidate states)` —
//! no clocks, no threads — so it unit-tests in isolation and the fleet's
//! determinism guarantee ("same admissions + same event interleaving =
//! same outcome") extends to governor behavior. Coldness is a *logical*
//! clock (submit counter), never wall time, for the same reason.

use crate::coordinator::replay::ReplayBuffer;
use crate::fleet::tenant::TenantId;

/// Default global budget: the paper's "less than 64 MB" headline.
pub const DEFAULT_BUDGET_BYTES: usize = 64 * 1024 * 1024;

/// Governor policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct GovernorConfig {
    /// global byte budget over shared backbone + all tenants
    pub budget_bytes: usize,
    /// demotion floor: packed buffers are never demoted below this width
    /// (the paper's accuracy cliff sits below 7 bits)
    pub min_bits: u8,
    /// shrink floor: replay capacity is never shrunk below this
    pub min_slots: usize,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig { budget_bytes: DEFAULT_BUDGET_BYTES, min_bits: 7, min_slots: 32 }
    }
}

/// One logged governor decision. `freed`/`bytes` are actual measured
/// deltas (committed after execution), not estimates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GovernorAction {
    Admit { tenant: TenantId, bytes: usize },
    Demote { tenant: TenantId, from_bits: u8, to_bits: u8, freed: usize },
    Shrink { tenant: TenantId, from_slots: usize, to_slots: usize, freed: usize },
    Evict { tenant: TenantId, freed: usize },
    Restore { tenant: TenantId, bytes: usize },
    Reject { needed: usize, short_by: usize },
}

/// What the planner needs to know about one live tenant.
#[derive(Clone, Copy, Debug)]
pub struct TenantFootprint {
    pub tenant: TenantId,
    /// logical-clock stamp of the last submitted event (smaller = colder)
    pub last_active: u64,
    pub bits: u8,
    pub slots: usize,
    pub latent_elems: usize,
}

/// One planned pressure-relief step (the server executes these under the
/// tenant locks, then commits the measured result to the log).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannedAction {
    Demote { tenant: TenantId, to_bits: u8 },
    Shrink { tenant: TenantId, to_slots: usize },
}

pub struct MemoryGovernor {
    cfg: GovernorConfig,
    /// bytes currently charged: shared backbone + per-tenant overhead +
    /// live replay arenas
    in_use: usize,
    log: Vec<GovernorAction>,
}

impl MemoryGovernor {
    /// `fixed_bytes` is charged up front: the shared frozen backbone (one
    /// copy per host, per the Arc-shared backbone design).
    pub fn new(cfg: GovernorConfig, fixed_bytes: usize) -> MemoryGovernor {
        assert!(
            fixed_bytes <= cfg.budget_bytes,
            "shared backbone ({fixed_bytes} B) alone exceeds the governor budget ({} B)",
            cfg.budget_bytes
        );
        MemoryGovernor { cfg, in_use: fixed_bytes, log: Vec::new() }
    }

    pub fn config(&self) -> &GovernorConfig {
        &self.cfg
    }

    pub fn bytes_in_use(&self) -> usize {
        self.in_use
    }

    pub fn bytes_free(&self) -> usize {
        self.cfg.budget_bytes - self.in_use
    }

    pub fn log(&self) -> &[GovernorAction] {
        &self.log
    }

    /// Plan pressure relief for an admission needing `needed` bytes:
    /// walk candidates coldest-first (ties by id — fully deterministic),
    /// demoting 8→7-bit first (cheap: ~12.5% of the arena back, zero
    /// slots lost), then shrinking slot counts toward `min_slots` in
    /// halving steps. Returns the step list and whether the projected
    /// free space covers `needed`.
    ///
    /// Pure: no state is touched. The server executes the steps and
    /// commits measured deltas via [`MemoryGovernor::commit`].
    pub fn plan_relief(
        &self,
        needed: usize,
        candidates: &[TenantFootprint],
    ) -> (Vec<PlannedAction>, bool) {
        let mut actions = Vec::new();
        let mut free = self.bytes_free();
        if free >= needed {
            return (actions, true);
        }
        let mut order: Vec<&TenantFootprint> = candidates.iter().collect();
        order.sort_by_key(|c| (c.last_active, c.tenant));

        // pass 1: bit demotion, coldest first
        for c in &order {
            if free >= needed {
                break;
            }
            if c.bits != 32 && c.bits > self.cfg.min_bits {
                let to = self.cfg.min_bits;
                if (c.latent_elems * to as usize) % 8 != 0 {
                    continue; // slots would lose byte alignment
                }
                let gain = ReplayBuffer::arena_bytes_for(c.slots, c.latent_elems, c.bits)
                    - ReplayBuffer::arena_bytes_for(c.slots, c.latent_elems, to);
                actions.push(PlannedAction::Demote { tenant: c.tenant, to_bits: to });
                free += gain;
            }
        }
        // pass 2: slot shrinking, coldest first, halving down to the floor
        let mut slots_now: Vec<(TenantId, usize, u8, usize)> = order
            .iter()
            .map(|c| {
                let bits = if c.bits != 32
                    && c.bits > self.cfg.min_bits
                    && (c.latent_elems * self.cfg.min_bits as usize) % 8 == 0
                {
                    self.cfg.min_bits // pass 1 already demoted it
                } else {
                    c.bits
                };
                (c.tenant, c.slots, bits, c.latent_elems)
            })
            .collect();
        let mut progressed = true;
        while free < needed && progressed {
            progressed = false;
            for entry in slots_now.iter_mut() {
                if free >= needed {
                    break;
                }
                let (tenant, slots, bits, elems) = *entry;
                let target = (slots / 2).max(self.cfg.min_slots);
                if target >= slots {
                    continue;
                }
                let gain = ReplayBuffer::bytes_for(slots, elems, bits)
                    - ReplayBuffer::bytes_for(target, elems, bits);
                actions.push(PlannedAction::Shrink { tenant, to_slots: target });
                free += gain;
                entry.1 = target;
                progressed = true;
            }
        }
        (actions, free >= needed)
    }

    /// Record an executed action and adjust the running total.
    pub fn commit(&mut self, action: GovernorAction) {
        match action {
            GovernorAction::Admit { bytes, .. } | GovernorAction::Restore { bytes, .. } => {
                self.in_use += bytes;
            }
            GovernorAction::Demote { freed, .. }
            | GovernorAction::Shrink { freed, .. }
            | GovernorAction::Evict { freed, .. } => {
                debug_assert!(freed <= self.in_use);
                self.in_use -= freed;
            }
            GovernorAction::Reject { .. } => {}
        }
        self.log.push(action);
    }

    /// Count of logged actions of each flavor, for reports:
    /// `(admits, demotes, shrinks, evicts, rejects)`.
    pub fn tally(&self) -> (usize, usize, usize, usize, usize) {
        let mut t = (0, 0, 0, 0, 0);
        for a in &self.log {
            match a {
                GovernorAction::Admit { .. } => t.0 += 1,
                GovernorAction::Demote { .. } => t.1 += 1,
                GovernorAction::Shrink { .. } => t.2 += 1,
                GovernorAction::Evict { .. } => t.3 += 1,
                GovernorAction::Restore { .. } => t.0 += 1,
                GovernorAction::Reject { .. } => t.4 += 1,
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(tenant: TenantId, last_active: u64, bits: u8, slots: usize) -> TenantFootprint {
        TenantFootprint { tenant, last_active, bits, slots, latent_elems: 256 }
    }

    #[test]
    fn fits_without_relief_when_budget_allows() {
        let g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 10_000, ..Default::default() },
            1_000,
        );
        let (actions, ok) = g.plan_relief(5_000, &[fp(0, 5, 8, 256)]);
        assert!(ok && actions.is_empty());
    }

    #[test]
    fn demotes_coldest_first_then_shrinks() {
        // budget exactly consumed; relief must demote tenant 1 (colder)
        // before tenant 0, and only shrink if demotion is not enough
        let mut g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 100_000, min_bits: 7, min_slots: 16 },
            0,
        );
        // two tenants at Q8, 128 slots x 256 elems = 32768 B arenas
        g.commit(GovernorAction::Admit {
            tenant: 0,
            bytes: ReplayBuffer::bytes_for(128, 256, 8),
        });
        g.commit(GovernorAction::Admit {
            tenant: 1,
            bytes: ReplayBuffer::bytes_for(128, 256, 8),
        });
        let free = g.bytes_free();
        // ask for slightly more than free: one demotion (4096 B) covers it
        let (actions, ok) = g.plan_relief(free + 4_000, &[fp(0, 9, 8, 128), fp(1, 2, 8, 128)]);
        assert!(ok);
        assert_eq!(actions, vec![PlannedAction::Demote { tenant: 1, to_bits: 7 }]);
        // ask for more than both demotions can free: shrinking kicks in,
        // still coldest first
        let (actions2, ok2) =
            g.plan_relief(free + 10_000, &[fp(0, 9, 8, 128), fp(1, 2, 8, 128)]);
        assert!(ok2);
        assert_eq!(actions2[0], PlannedAction::Demote { tenant: 1, to_bits: 7 });
        assert_eq!(actions2[1], PlannedAction::Demote { tenant: 0, to_bits: 7 });
        assert!(matches!(actions2[2], PlannedAction::Shrink { tenant: 1, .. }));
    }

    #[test]
    fn shrink_halves_down_to_floor_and_reports_infeasible() {
        let g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 50_000, min_bits: 7, min_slots: 16 },
            49_000,
        );
        // one tiny warm tenant: even full relief cannot find a megabyte
        let (actions, ok) = g.plan_relief(1_000_000, &[fp(0, 1, 8, 64)]);
        assert!(!ok);
        // demote + shrink 64 -> 32 -> 16, then stuck at the floor
        assert_eq!(
            actions,
            vec![
                PlannedAction::Demote { tenant: 0, to_bits: 7 },
                PlannedAction::Shrink { tenant: 0, to_slots: 32 },
                PlannedAction::Shrink { tenant: 0, to_slots: 16 },
            ]
        );
    }

    #[test]
    fn fp32_and_misaligned_tenants_skip_demotion() {
        let g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 1_000_000, min_bits: 7, min_slots: 16 },
            999_000,
        );
        let mut odd = fp(0, 1, 8, 64);
        odd.latent_elems = 12; // 12 * 7 = 84 bits: not byte-aligned
        let f32t = fp(1, 2, 32, 64);
        let (actions, _) = g.plan_relief(2_000, &[odd, f32t]);
        assert!(
            actions.iter().all(|a| !matches!(a, PlannedAction::Demote { .. })),
            "must not demote FP32 or misaligned tenants: {actions:?}"
        );
    }

    #[test]
    fn commit_tracks_running_total_and_tally() {
        let mut g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 10_000, ..Default::default() },
            2_000,
        );
        g.commit(GovernorAction::Admit { tenant: 0, bytes: 3_000 });
        assert_eq!(g.bytes_in_use(), 5_000);
        g.commit(GovernorAction::Demote { tenant: 0, from_bits: 8, to_bits: 7, freed: 400 });
        assert_eq!(g.bytes_in_use(), 4_600);
        g.commit(GovernorAction::Evict { tenant: 0, freed: 2_600 });
        assert_eq!(g.bytes_in_use(), 2_000);
        g.commit(GovernorAction::Reject { needed: 99, short_by: 9 });
        assert_eq!(g.tally(), (1, 1, 0, 1, 1));
        assert_eq!(g.log().len(), 4);
    }

    #[test]
    #[should_panic(expected = "exceeds the governor budget")]
    fn oversized_backbone_rejected() {
        let _ = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 1_000, ..Default::default() },
            2_000,
        );
    }
}
