//! The fleet's global memory governor (paper §III-B applied host-wide).
//!
//! The paper shows a single learner fits a 64 MB envelope because 8-bit
//! latent replays are ~lossless at 4x compression — and Ravaglia et al.'s
//! memory-latency-accuracy trade-off study (PAPERS.md) frames bit-width
//! as a *runtime knob*, not a compile-time constant. The governor takes
//! that literally and runs the budget as a **three-tier hierarchy**:
//!
//! - **hot**: 8-bit packed replays in RAM (full paper accuracy);
//! - **warm**: 7-bit packed replays in RAM (the 8→7-bit in-place
//!   demotion, ~12.5% of the arena back, ≤ S₇/2 extra error);
//! - **cold**: the whole tenant serialized to a disk snapshot
//!   (`fleet::snapshot`), RAM charge zero, restored lazily on its next
//!   event.
//!
//! Under admission pressure the **coldest** tenants pay first: demotion,
//! then (when the spill tier is enabled) a lossless spill to disk, and
//! only past that the lossy slot shrink. When pressure clears the
//! governor runs the ladder in reverse — spilled tenants are readmitted
//! and warm tenants re-widened 7→8-bit (`promote`) — under **watermark
//! hysteresis**: boosts run only while usage sits below the low
//! watermark and stop at the high watermark, so a boost can never
//! trigger the very pressure that would undo it (no thrash without new
//! external demand).
//!
//! The policy is a pure function of `(needed bytes, candidate states)` —
//! no clocks, no threads, no filesystem — so it unit-tests in isolation
//! and the fleet's determinism guarantee ("same admissions + same event
//! interleaving = same outcome") extends to governor behavior. Coldness
//! is a *logical* clock (submit counter), never wall time, for the same
//! reason.

use crate::coordinator::replay::ReplayBuffer;
use crate::fleet::tenant::TenantId;

/// Default global budget: the paper's "less than 64 MB" headline.
pub const DEFAULT_BUDGET_BYTES: usize = 64 * 1024 * 1024;

/// Governor policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct GovernorConfig {
    /// global byte budget over shared backbone + all tenants
    pub budget_bytes: usize,
    /// demotion floor: packed buffers are never demoted below this width
    /// (the paper's accuracy cliff sits below 7 bits)
    pub min_bits: u8,
    /// shrink floor: replay capacity is never shrunk below this
    pub min_slots: usize,
    /// boost trigger (fraction of budget): unspills/promotions run only
    /// while `bytes_in_use < low_watermark * budget_bytes`
    pub low_watermark: f64,
    /// boost ceiling (fraction of budget): boosts stop once the
    /// projected usage would cross `high_watermark * budget_bytes` —
    /// the hysteresis gap between the two watermarks is what keeps the
    /// demote/promote ladder from thrashing
    pub high_watermark: f64,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig {
            budget_bytes: DEFAULT_BUDGET_BYTES,
            min_bits: 7,
            min_slots: 32,
            low_watermark: 0.60,
            high_watermark: 0.85,
        }
    }
}

/// One logged governor decision. `freed`/`bytes` are actual measured
/// deltas (committed after execution), not estimates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GovernorAction {
    Admit { tenant: TenantId, bytes: usize },
    Demote { tenant: TenantId, from_bits: u8, to_bits: u8, freed: usize },
    /// 7→8-bit re-widen when pressure cleared: the RAM charge *grows*
    Promote { tenant: TenantId, from_bits: u8, to_bits: u8, grew: usize },
    Shrink { tenant: TenantId, from_slots: usize, to_slots: usize, freed: usize },
    /// tenant serialized to the cold tier: RAM freed, disk charged
    Spill { tenant: TenantId, freed: usize, disk_bytes: usize },
    /// tenant readmitted from the cold tier (lazy restore or rebalance):
    /// RAM recharged, disk released
    Unspill { tenant: TenantId, bytes: usize, disk_freed: usize },
    Evict { tenant: TenantId, freed: usize },
    Restore { tenant: TenantId, bytes: usize },
    /// crash-recovery scan found a valid snapshot in the spill directory
    /// at server start: the tenant re-enters the cold tier (disk
    /// charged, zero RAM — its spill predates this process)
    Recover { tenant: TenantId, disk_bytes: usize },
    /// unrecoverable restore-corruption survived: the snapshot was
    /// quarantined and the tenant rebuilt resident with an **empty**
    /// replay buffer — RAM recharged at the rebuilt footprint (`bytes`),
    /// disk released (`disk_freed`). The accuracy cost is explicit in
    /// the log; the tenant is never lost.
    Degrade { tenant: TenantId, bytes: usize, disk_freed: usize },
    Reject { needed: usize, short_by: usize },
}

impl GovernorAction {
    /// Stable numeric tag — the `a` payload word of a
    /// `telemetry::EventKind::Governor` event.
    pub fn kind_tag(&self) -> u64 {
        match self {
            GovernorAction::Admit { .. } => 0,
            GovernorAction::Demote { .. } => 1,
            GovernorAction::Promote { .. } => 2,
            GovernorAction::Shrink { .. } => 3,
            GovernorAction::Spill { .. } => 4,
            GovernorAction::Unspill { .. } => 5,
            GovernorAction::Evict { .. } => 6,
            GovernorAction::Restore { .. } => 7,
            GovernorAction::Recover { .. } => 8,
            GovernorAction::Degrade { .. } => 9,
            GovernorAction::Reject { .. } => 10,
        }
    }

    pub fn kind_str(&self) -> &'static str {
        match self {
            GovernorAction::Admit { .. } => "admit",
            GovernorAction::Demote { .. } => "demote",
            GovernorAction::Promote { .. } => "promote",
            GovernorAction::Shrink { .. } => "shrink",
            GovernorAction::Spill { .. } => "spill",
            GovernorAction::Unspill { .. } => "unspill",
            GovernorAction::Evict { .. } => "evict",
            GovernorAction::Restore { .. } => "restore",
            GovernorAction::Recover { .. } => "recover",
            GovernorAction::Degrade { .. } => "degrade",
            GovernorAction::Reject { .. } => "reject",
        }
    }

    /// The tenant this action touched (`None` for budget-level actions).
    pub fn tenant_id(&self) -> Option<TenantId> {
        match *self {
            GovernorAction::Admit { tenant, .. }
            | GovernorAction::Demote { tenant, .. }
            | GovernorAction::Promote { tenant, .. }
            | GovernorAction::Shrink { tenant, .. }
            | GovernorAction::Spill { tenant, .. }
            | GovernorAction::Unspill { tenant, .. }
            | GovernorAction::Evict { tenant, .. }
            | GovernorAction::Restore { tenant, .. }
            | GovernorAction::Recover { tenant, .. }
            | GovernorAction::Degrade { tenant, .. } => Some(tenant),
            GovernorAction::Reject { .. } => None,
        }
    }

    /// RAM bytes this action moved (charged or released) — the `b`
    /// payload word of the telemetry event.
    pub fn bytes_moved(&self) -> u64 {
        (match *self {
            GovernorAction::Admit { bytes, .. } => bytes,
            GovernorAction::Demote { freed, .. } => freed,
            GovernorAction::Promote { grew, .. } => grew,
            GovernorAction::Shrink { freed, .. } => freed,
            GovernorAction::Spill { freed, .. } => freed,
            GovernorAction::Unspill { bytes, .. } => bytes,
            GovernorAction::Evict { freed, .. } => freed,
            GovernorAction::Restore { bytes, .. } => bytes,
            GovernorAction::Recover { disk_bytes, .. } => disk_bytes,
            GovernorAction::Degrade { bytes, .. } => bytes,
            GovernorAction::Reject { short_by, .. } => short_by,
        }) as u64
    }

    /// Human-readable one-liner (rendered behind `TINYCL_LOG`).
    pub fn describe(&self) -> String {
        match *self {
            GovernorAction::Admit { tenant, bytes } => {
                format!("admit tenant {tenant}: +{bytes} B")
            }
            GovernorAction::Demote { tenant, from_bits, to_bits, freed } => {
                format!("demote tenant {tenant}: {from_bits}->{to_bits} bit, -{freed} B")
            }
            GovernorAction::Promote { tenant, from_bits, to_bits, grew } => {
                format!("promote tenant {tenant}: {from_bits}->{to_bits} bit, +{grew} B")
            }
            GovernorAction::Shrink { tenant, from_slots, to_slots, freed } => {
                format!("shrink tenant {tenant}: {from_slots}->{to_slots} slots, -{freed} B")
            }
            GovernorAction::Spill { tenant, freed, disk_bytes } => {
                format!("spill tenant {tenant}: -{freed} B RAM, +{disk_bytes} B disk")
            }
            GovernorAction::Unspill { tenant, bytes, disk_freed } => {
                format!("unspill tenant {tenant}: +{bytes} B RAM, -{disk_freed} B disk")
            }
            GovernorAction::Evict { tenant, freed } => {
                format!("evict tenant {tenant}: -{freed} B")
            }
            GovernorAction::Restore { tenant, bytes } => {
                format!("restore tenant {tenant}: +{bytes} B")
            }
            GovernorAction::Recover { tenant, disk_bytes } => {
                format!("recover tenant {tenant}: +{disk_bytes} B disk")
            }
            GovernorAction::Degrade { tenant, bytes, disk_freed } => {
                format!(
                    "degrade tenant {tenant}: rebuilt empty (+{bytes} B RAM, \
                     -{disk_freed} B disk)"
                )
            }
            GovernorAction::Reject { needed, short_by } => {
                format!("reject: needed {needed} B, short by {short_by} B")
            }
        }
    }
}

/// What the planner needs to know about one live tenant.
#[derive(Clone, Copy, Debug)]
pub struct TenantFootprint {
    pub tenant: TenantId,
    /// logical-clock stamp of the last submitted event (smaller = colder)
    pub last_active: u64,
    pub bits: u8,
    /// the tenant's *configured* storage width — the promotion ceiling
    /// (a tenant deployed at Q7 is never "promoted" past its config)
    pub cfg_bits: u8,
    pub slots: usize,
    pub latent_elems: usize,
    /// fixed per-tenant overhead (params + grads + activations) that a
    /// spill releases on top of the replay arena
    pub overhead: usize,
}

/// What the boost planner needs to know about one spilled tenant.
#[derive(Clone, Copy, Debug)]
pub struct SpilledFootprint {
    pub tenant: TenantId,
    /// logical-clock stamp at spill time (larger = warmer = readmit first)
    pub last_active: u64,
    /// RAM bytes a readmission will recharge (overhead + replay)
    pub ram_bytes: usize,
}

/// One planned pressure-relief step (the server executes these under the
/// tenant locks, then commits the measured result to the log).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannedAction {
    Demote { tenant: TenantId, to_bits: u8 },
    Spill { tenant: TenantId },
    Shrink { tenant: TenantId, to_slots: usize },
}

/// One planned pressure-cleared boost step (the reverse ladder).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannedBoost {
    Unspill { tenant: TenantId },
    Promote { tenant: TenantId, to_bits: u8 },
}

/// Which rungs of the relief ladder a plan may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReliefMode {
    /// demote → shrink (no cold tier configured)
    Degrade,
    /// demote → spill → shrink (the full three-tier ladder)
    DegradeAndSpill,
    /// spill only — the **lossless** mode the serving path uses for
    /// lazy restores: replay contents are never altered mid-run, so
    /// per-tenant training outcomes stay independent of worker
    /// scheduling (the determinism guarantee)
    SpillOnly,
}

/// Log tallies by action flavor (see [`MemoryGovernor::tally`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GovernorTally {
    pub admits: usize,
    pub restores: usize,
    pub demotes: usize,
    pub promotes: usize,
    pub shrinks: usize,
    pub spills: usize,
    pub unspills: usize,
    pub evicts: usize,
    /// cold-tier snapshots re-registered by the crash-recovery scan
    pub recovers: usize,
    /// corrupted-snapshot survivals: quarantine + empty-replay rebuild
    pub degrades: usize,
    pub rejects: usize,
}

pub struct MemoryGovernor {
    cfg: GovernorConfig,
    /// bytes currently charged: shared backbone + per-tenant overhead +
    /// live replay arenas
    in_use: usize,
    /// bytes of tenant snapshots currently parked in the cold tier
    spilled_disk: usize,
    log: Vec<GovernorAction>,
}

impl MemoryGovernor {
    /// `fixed_bytes` is charged up front: the shared frozen backbone (one
    /// copy per host, per the Arc-shared backbone design).
    pub fn new(cfg: GovernorConfig, fixed_bytes: usize) -> MemoryGovernor {
        assert!(
            fixed_bytes <= cfg.budget_bytes,
            "shared backbone ({fixed_bytes} B) alone exceeds the governor budget ({} B)",
            cfg.budget_bytes
        );
        assert!(
            cfg.low_watermark > 0.0
                && cfg.low_watermark <= cfg.high_watermark
                && cfg.high_watermark <= 1.0,
            "watermarks must satisfy 0 < low <= high <= 1 (got {} / {})",
            cfg.low_watermark,
            cfg.high_watermark
        );
        MemoryGovernor { cfg, in_use: fixed_bytes, spilled_disk: 0, log: Vec::new() }
    }

    pub fn config(&self) -> &GovernorConfig {
        &self.cfg
    }

    pub fn bytes_in_use(&self) -> usize {
        self.in_use
    }

    pub fn bytes_free(&self) -> usize {
        self.cfg.budget_bytes - self.in_use
    }

    /// Cold-tier footprint: snapshot bytes currently on disk. NOT part
    /// of [`MemoryGovernor::bytes_in_use`] — disk is the tier the RAM
    /// budget spills *into*.
    pub fn spilled_disk_bytes(&self) -> usize {
        self.spilled_disk
    }

    /// Boost trigger threshold in bytes (`low_watermark * budget`).
    pub fn low_bytes(&self) -> usize {
        (self.cfg.low_watermark * self.cfg.budget_bytes as f64) as usize
    }

    /// Boost ceiling in bytes (`high_watermark * budget`).
    pub fn high_bytes(&self) -> usize {
        (self.cfg.high_watermark * self.cfg.budget_bytes as f64) as usize
    }

    pub fn log(&self) -> &[GovernorAction] {
        &self.log
    }

    /// Apply a budget shock: resize the global envelope in place. The
    /// caller (the server's shock path) must have already relieved
    /// pressure down to the new size — shrinking below the bytes
    /// currently charged would make `bytes_free` underflow.
    pub fn set_budget(&mut self, budget_bytes: usize) {
        assert!(
            budget_bytes >= self.in_use,
            "budget shock to {budget_bytes} B below the {} B currently in use",
            self.in_use
        );
        self.cfg.budget_bytes = budget_bytes;
    }

    /// Plan pressure relief for an admission needing `needed` bytes:
    /// walk candidates coldest-first (ties by id — fully deterministic)
    /// down the tier ladder `mode` allows — 8→7-bit demotion (cheap:
    /// ~12.5% of the arena back, zero slots lost), then whole-tenant
    /// spill to the cold tier (lossless: the snapshot round-trips
    /// bit-exact), then slot shrinking toward `min_slots` in halving
    /// steps (lossy, last resort). Returns the step list and whether the
    /// projected free space covers `needed`.
    ///
    /// Pure: no state is touched. The server executes the steps and
    /// commits measured deltas via [`MemoryGovernor::commit`].
    pub fn plan_relief(
        &self,
        needed: usize,
        candidates: &[TenantFootprint],
        mode: ReliefMode,
    ) -> (Vec<PlannedAction>, bool) {
        let mut actions = Vec::new();
        let mut free = self.bytes_free();
        if free >= needed {
            return (actions, true);
        }
        let mut order: Vec<&TenantFootprint> = candidates.iter().collect();
        order.sort_by_key(|c| (c.last_active, c.tenant));

        // running view of each candidate through the passes:
        // (footprint, bits_now, spilled)
        let mut state: Vec<(&TenantFootprint, u8, bool)> =
            order.iter().map(|c| (*c, c.bits, false)).collect();

        // pass 1: bit demotion, coldest first
        if mode != ReliefMode::SpillOnly {
            for entry in state.iter_mut() {
                if free >= needed {
                    break;
                }
                let c = entry.0;
                if c.bits != 32
                    && c.bits > self.cfg.min_bits
                    && (c.latent_elems * self.cfg.min_bits as usize) % 8 == 0
                {
                    let to = self.cfg.min_bits;
                    let gain = ReplayBuffer::arena_bytes_for(c.slots, c.latent_elems, c.bits)
                        - ReplayBuffer::arena_bytes_for(c.slots, c.latent_elems, to);
                    actions.push(PlannedAction::Demote { tenant: c.tenant, to_bits: to });
                    free += gain;
                    entry.1 = to;
                }
            }
        }
        // pass 2: spill to the cold tier, coldest first (lossless — the
        // whole tenant, parked reorder buffer included, leaves RAM and
        // waits on disk for its next event)
        if mode != ReliefMode::Degrade {
            for entry in state.iter_mut() {
                if free >= needed {
                    break;
                }
                let (c, bits_now, _) = *entry;
                let gain = c.overhead
                    + ReplayBuffer::bytes_for(c.slots, c.latent_elems, bits_now);
                actions.push(PlannedAction::Spill { tenant: c.tenant });
                free += gain;
                entry.2 = true;
            }
        }
        // pass 3: slot shrinking of whoever is still resident, coldest
        // first, halving down to the floor
        if mode != ReliefMode::SpillOnly {
            let mut slots_now: Vec<(TenantId, usize, u8, usize)> = state
                .iter()
                .filter(|(_, _, spilled)| !spilled)
                .map(|&(c, bits_now, _)| (c.tenant, c.slots, bits_now, c.latent_elems))
                .collect();
            let mut progressed = true;
            while free < needed && progressed {
                progressed = false;
                for entry in slots_now.iter_mut() {
                    if free >= needed {
                        break;
                    }
                    let (tenant, slots, bits, elems) = *entry;
                    let target = (slots / 2).max(self.cfg.min_slots);
                    if target >= slots {
                        continue;
                    }
                    let gain = ReplayBuffer::bytes_for(slots, elems, bits)
                        - ReplayBuffer::bytes_for(target, elems, bits);
                    actions.push(PlannedAction::Shrink { tenant, to_slots: target });
                    free += gain;
                    entry.1 = target;
                    progressed = true;
                }
            }
        }
        (actions, free >= needed)
    }

    /// Plan the pressure-cleared reverse ladder: re-widen 7-bit
    /// residents back to their configured width, then readmit spilled
    /// tenants, warmest first. Residents go first because they are the
    /// ones actively serving traffic and a promotion costs only ~12.5%
    /// of one arena, while a readmission recharges a whole tenant (and
    /// a spilled tenant with live traffic gets lazily restored by the
    /// serving path anyway). Gated by the watermarks — an empty plan
    /// unless `bytes_in_use < low_watermark * budget`, and each step
    /// must keep the projected usage at or below
    /// `high_watermark * budget` (hysteresis: a boost can never create
    /// the pressure that would immediately undo it).
    ///
    /// Pure, like [`MemoryGovernor::plan_relief`].
    pub fn plan_boost(
        &self,
        resident: &[TenantFootprint],
        spilled: &[SpilledFootprint],
    ) -> Vec<PlannedBoost> {
        let mut boosts = Vec::new();
        if self.in_use >= self.low_bytes() {
            return boosts;
        }
        let ceiling = self.high_bytes();
        let mut projected = self.in_use;
        // 7→8-bit promotions of resident tenants, warmest first
        let mut warm: Vec<&TenantFootprint> = resident
            .iter()
            .filter(|c| {
                c.bits != 32
                    && c.bits < c.cfg_bits
                    && c.cfg_bits != 32
                    && (c.latent_elems * c.cfg_bits as usize) % 8 == 0
            })
            .collect();
        warm.sort_by_key(|c| (std::cmp::Reverse(c.last_active), c.tenant));
        for c in warm {
            let grow = ReplayBuffer::arena_bytes_for(c.slots, c.latent_elems, c.cfg_bits)
                - ReplayBuffer::arena_bytes_for(c.slots, c.latent_elems, c.bits);
            if projected + grow <= ceiling {
                boosts.push(PlannedBoost::Promote { tenant: c.tenant, to_bits: c.cfg_bits });
                projected += grow;
            }
        }
        // then cold-tier readmissions, warmest spilled first
        let mut cold: Vec<&SpilledFootprint> = spilled.iter().collect();
        cold.sort_by_key(|s| (std::cmp::Reverse(s.last_active), s.tenant));
        for s in cold {
            if projected + s.ram_bytes <= ceiling {
                boosts.push(PlannedBoost::Unspill { tenant: s.tenant });
                projected += s.ram_bytes;
            }
        }
        boosts
    }

    /// Record an executed action and adjust the running totals.
    pub fn commit(&mut self, action: GovernorAction) {
        match action {
            GovernorAction::Admit { bytes, .. } | GovernorAction::Restore { bytes, .. } => {
                self.in_use += bytes;
            }
            GovernorAction::Promote { grew, .. } => {
                self.in_use += grew;
            }
            GovernorAction::Demote { freed, .. }
            | GovernorAction::Shrink { freed, .. }
            | GovernorAction::Evict { freed, .. } => {
                debug_assert!(freed <= self.in_use);
                self.in_use -= freed;
            }
            GovernorAction::Spill { freed, disk_bytes, .. } => {
                debug_assert!(freed <= self.in_use);
                self.in_use -= freed;
                self.spilled_disk += disk_bytes;
            }
            GovernorAction::Unspill { bytes, disk_freed, .. } => {
                self.in_use += bytes;
                debug_assert!(disk_freed <= self.spilled_disk);
                self.spilled_disk -= disk_freed;
            }
            GovernorAction::Recover { disk_bytes, .. } => {
                self.spilled_disk += disk_bytes;
            }
            GovernorAction::Degrade { bytes, disk_freed, .. } => {
                self.in_use += bytes;
                debug_assert!(disk_freed <= self.spilled_disk);
                self.spilled_disk -= disk_freed;
            }
            GovernorAction::Reject { .. } => {}
        }
        self.log.push(action);
    }

    /// Count of logged actions of each flavor, for reports.
    pub fn tally(&self) -> GovernorTally {
        let mut t = GovernorTally::default();
        for a in &self.log {
            match a {
                GovernorAction::Admit { .. } => t.admits += 1,
                GovernorAction::Restore { .. } => t.restores += 1,
                GovernorAction::Demote { .. } => t.demotes += 1,
                GovernorAction::Promote { .. } => t.promotes += 1,
                GovernorAction::Shrink { .. } => t.shrinks += 1,
                GovernorAction::Spill { .. } => t.spills += 1,
                GovernorAction::Unspill { .. } => t.unspills += 1,
                GovernorAction::Evict { .. } => t.evicts += 1,
                GovernorAction::Recover { .. } => t.recovers += 1,
                GovernorAction::Degrade { .. } => t.degrades += 1,
                GovernorAction::Reject { .. } => t.rejects += 1,
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fp(tenant: TenantId, last_active: u64, bits: u8, slots: usize) -> TenantFootprint {
        TenantFootprint {
            tenant,
            last_active,
            bits,
            cfg_bits: 8,
            slots,
            latent_elems: 256,
            overhead: 10_000,
        }
    }

    #[test]
    fn fits_without_relief_when_budget_allows() {
        let g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 10_000, ..Default::default() },
            1_000,
        );
        let (actions, ok) = g.plan_relief(5_000, &[fp(0, 5, 8, 256)], ReliefMode::Degrade);
        assert!(ok && actions.is_empty());
    }

    #[test]
    fn demotes_coldest_first_then_shrinks() {
        // budget exactly consumed; relief must demote tenant 1 (colder)
        // before tenant 0, and only shrink if demotion is not enough
        let mut g = MemoryGovernor::new(
            GovernorConfig {
                budget_bytes: 100_000,
                min_bits: 7,
                min_slots: 16,
                ..Default::default()
            },
            0,
        );
        // two tenants at Q8, 128 slots x 256 elems = 32768 B arenas
        g.commit(GovernorAction::Admit {
            tenant: 0,
            bytes: ReplayBuffer::bytes_for(128, 256, 8),
        });
        g.commit(GovernorAction::Admit {
            tenant: 1,
            bytes: ReplayBuffer::bytes_for(128, 256, 8),
        });
        let free = g.bytes_free();
        // ask for slightly more than free: one demotion (4096 B) covers it
        let (actions, ok) = g.plan_relief(
            free + 4_000,
            &[fp(0, 9, 8, 128), fp(1, 2, 8, 128)],
            ReliefMode::Degrade,
        );
        assert!(ok);
        assert_eq!(actions, vec![PlannedAction::Demote { tenant: 1, to_bits: 7 }]);
        // ask for more than both demotions can free: shrinking kicks in,
        // still coldest first
        let (actions2, ok2) = g.plan_relief(
            free + 10_000,
            &[fp(0, 9, 8, 128), fp(1, 2, 8, 128)],
            ReliefMode::Degrade,
        );
        assert!(ok2);
        assert_eq!(actions2[0], PlannedAction::Demote { tenant: 1, to_bits: 7 });
        assert_eq!(actions2[1], PlannedAction::Demote { tenant: 0, to_bits: 7 });
        assert!(matches!(actions2[2], PlannedAction::Shrink { tenant: 1, .. }));
    }

    #[test]
    fn spill_tier_sits_between_demotion_and_shrinking() {
        // same pressure as above, but with the cold tier enabled: after
        // both demotions the plan spills the coldest tenant whole — and
        // never reaches the lossy shrink pass
        let mut g = MemoryGovernor::new(
            GovernorConfig {
                budget_bytes: 100_000,
                min_bits: 7,
                min_slots: 16,
                ..Default::default()
            },
            0,
        );
        g.commit(GovernorAction::Admit { tenant: 0, bytes: ReplayBuffer::bytes_for(128, 256, 8) });
        g.commit(GovernorAction::Admit { tenant: 1, bytes: ReplayBuffer::bytes_for(128, 256, 8) });
        let free = g.bytes_free();
        let (actions, ok) = g.plan_relief(
            free + 10_000,
            &[fp(0, 9, 8, 128), fp(1, 2, 8, 128)],
            ReliefMode::DegradeAndSpill,
        );
        assert!(ok);
        assert_eq!(
            actions,
            vec![
                PlannedAction::Demote { tenant: 1, to_bits: 7 },
                PlannedAction::Demote { tenant: 0, to_bits: 7 },
                PlannedAction::Spill { tenant: 1 },
            ]
        );
    }

    #[test]
    fn spill_only_mode_never_degrades() {
        // the serving path's lossless relief: no demotes, no shrinks,
        // only whole-tenant spills, coldest first and no more than needed
        let g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 100_000, ..Default::default() },
            95_000,
        );
        let (actions, ok) =
            g.plan_relief(40_000, &[fp(0, 5, 8, 128), fp(1, 1, 8, 128)], ReliefMode::SpillOnly);
        assert!(ok);
        // tenant 1 is colder (last_active 1 < 5) and its spill alone
        // covers the request
        assert_eq!(actions, vec![PlannedAction::Spill { tenant: 1 }]);
    }

    #[test]
    fn shrink_halves_down_to_floor_and_reports_infeasible() {
        let g = MemoryGovernor::new(
            GovernorConfig {
                budget_bytes: 50_000,
                min_bits: 7,
                min_slots: 16,
                ..Default::default()
            },
            49_000,
        );
        // one tiny warm tenant: even full relief cannot find a megabyte
        let (actions, ok) = g.plan_relief(1_000_000, &[fp(0, 1, 8, 64)], ReliefMode::Degrade);
        assert!(!ok);
        // demote + shrink 64 -> 32 -> 16, then stuck at the floor
        assert_eq!(
            actions,
            vec![
                PlannedAction::Demote { tenant: 0, to_bits: 7 },
                PlannedAction::Shrink { tenant: 0, to_slots: 32 },
                PlannedAction::Shrink { tenant: 0, to_slots: 16 },
            ]
        );
    }

    #[test]
    fn fp32_and_misaligned_tenants_skip_demotion() {
        let g = MemoryGovernor::new(
            GovernorConfig {
                budget_bytes: 1_000_000,
                min_bits: 7,
                min_slots: 16,
                ..Default::default()
            },
            999_000,
        );
        let mut odd = fp(0, 1, 8, 64);
        odd.latent_elems = 12; // 12 * 7 = 84 bits: not byte-aligned
        let f32t = fp(1, 2, 32, 64);
        let (actions, _) = g.plan_relief(2_000, &[odd, f32t], ReliefMode::Degrade);
        assert!(
            actions.iter().all(|a| !matches!(a, PlannedAction::Demote { .. })),
            "must not demote FP32 or misaligned tenants: {actions:?}"
        );
    }

    #[test]
    fn boost_gated_by_low_watermark() {
        // at 70% of a 100k budget with low=0.6: no boosts at all
        let g = MemoryGovernor::new(
            GovernorConfig {
                budget_bytes: 100_000,
                low_watermark: 0.6,
                high_watermark: 0.85,
                ..Default::default()
            },
            70_000,
        );
        let spilled = [SpilledFootprint { tenant: 3, last_active: 9, ram_bytes: 5_000 }];
        let mut warm = fp(0, 5, 7, 128);
        warm.bits = 7;
        assert!(g.plan_boost(&[warm], &spilled).is_empty());
    }

    #[test]
    fn boost_promotes_residents_then_unspills_warmest_up_to_high_watermark() {
        // 30k in use, low=60k, high=85k: the promotion (residents first,
        // +4096: 128 slots x 256 elems, 28672 -> 32768) runs before the
        // readmissions (warmest spilled first), and the ladder stops at
        // the ceiling
        let g = MemoryGovernor::new(
            GovernorConfig {
                budget_bytes: 100_000,
                low_watermark: 0.6,
                high_watermark: 0.85,
                ..Default::default()
            },
            30_000,
        );
        let spilled = [
            SpilledFootprint { tenant: 3, last_active: 2, ram_bytes: 20_000 },
            SpilledFootprint { tenant: 4, last_active: 9, ram_bytes: 20_000 },
        ];
        let mut warm = fp(0, 5, 7, 128);
        warm.bits = 7;
        let boosts = g.plan_boost(&[warm], &spilled);
        // promote (34096), unspill tenant 4 (54096), unspill tenant 3
        // (74096 <= 85k)
        assert_eq!(
            boosts,
            vec![
                PlannedBoost::Promote { tenant: 0, to_bits: 8 },
                PlannedBoost::Unspill { tenant: 4 },
                PlannedBoost::Unspill { tenant: 3 },
            ]
        );
        // with a lower ceiling the second readmission no longer fits,
        // but the (cheap) promotion always does
        let g2 = MemoryGovernor::new(
            GovernorConfig {
                budget_bytes: 100_000,
                low_watermark: 0.6,
                high_watermark: 0.72,
                ..Default::default()
            },
            30_000,
        );
        let boosts2 = g2.plan_boost(&[warm], &spilled);
        assert_eq!(
            boosts2,
            vec![
                PlannedBoost::Promote { tenant: 0, to_bits: 8 },
                PlannedBoost::Unspill { tenant: 4 },
            ]
        );
    }

    #[test]
    fn boost_never_promotes_past_configured_width() {
        let g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 100_000, ..Default::default() },
            1_000,
        );
        // deployed at Q7 and sitting at Q7: nothing to promote
        let mut native7 = fp(0, 5, 7, 64);
        native7.bits = 7;
        native7.cfg_bits = 7;
        // FP32 baseline arm: untouched
        let f32t = fp(1, 6, 32, 64);
        assert!(g.plan_boost(&[native7, f32t], &[]).is_empty());
    }

    #[test]
    fn commit_tracks_ram_and_disk_totals_and_tally() {
        let mut g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 100_000, ..Default::default() },
            2_000,
        );
        g.commit(GovernorAction::Admit { tenant: 0, bytes: 3_000 });
        assert_eq!(g.bytes_in_use(), 5_000);
        g.commit(GovernorAction::Demote { tenant: 0, from_bits: 8, to_bits: 7, freed: 400 });
        assert_eq!(g.bytes_in_use(), 4_600);
        g.commit(GovernorAction::Spill { tenant: 0, freed: 2_600, disk_bytes: 2_800 });
        assert_eq!(g.bytes_in_use(), 2_000);
        assert_eq!(g.spilled_disk_bytes(), 2_800);
        g.commit(GovernorAction::Unspill { tenant: 0, bytes: 2_600, disk_freed: 2_800 });
        assert_eq!(g.bytes_in_use(), 4_600);
        assert_eq!(g.spilled_disk_bytes(), 0);
        g.commit(GovernorAction::Promote { tenant: 0, from_bits: 7, to_bits: 8, grew: 400 });
        assert_eq!(g.bytes_in_use(), 5_000);
        g.commit(GovernorAction::Evict { tenant: 0, freed: 3_000 });
        assert_eq!(g.bytes_in_use(), 2_000);
        g.commit(GovernorAction::Reject { needed: 99, short_by: 9 });
        let t = g.tally();
        assert_eq!(
            t,
            GovernorTally {
                admits: 1,
                restores: 0,
                demotes: 1,
                promotes: 1,
                shrinks: 0,
                spills: 1,
                unspills: 1,
                evicts: 1,
                recovers: 0,
                degrades: 0,
                rejects: 1,
            }
        );
        assert_eq!(g.log().len(), 7);
    }

    #[test]
    fn degrade_recharges_ram_and_releases_the_quarantined_disk_bytes() {
        let mut g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 100_000, ..Default::default() },
            2_000,
        );
        g.commit(GovernorAction::Admit { tenant: 0, bytes: 3_000 });
        g.commit(GovernorAction::Spill { tenant: 0, freed: 3_000, disk_bytes: 3_200 });
        assert_eq!((g.bytes_in_use(), g.spilled_disk_bytes()), (2_000, 3_200));
        // the snapshot turned out corrupt: quarantine + rebuild with an
        // empty replay buffer (smaller RAM charge than the original)
        g.commit(GovernorAction::Degrade { tenant: 0, bytes: 2_400, disk_freed: 3_200 });
        assert_eq!((g.bytes_in_use(), g.spilled_disk_bytes()), (4_400, 0));
        assert_eq!(g.tally().degrades, 1);
    }

    #[test]
    fn budget_shock_resizes_the_envelope() {
        let mut g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 100_000, ..Default::default() },
            40_000,
        );
        g.set_budget(60_000);
        assert_eq!(g.bytes_free(), 20_000);
        g.set_budget(120_000);
        assert_eq!(g.bytes_free(), 80_000);
    }

    #[test]
    #[should_panic(expected = "budget shock")]
    fn budget_shock_below_current_usage_rejected() {
        let mut g = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 100_000, ..Default::default() },
            40_000,
        );
        g.set_budget(30_000);
    }

    #[test]
    #[should_panic(expected = "exceeds the governor budget")]
    fn oversized_backbone_rejected() {
        let _ = MemoryGovernor::new(
            GovernorConfig { budget_bytes: 1_000, ..Default::default() },
            2_000,
        );
    }

    #[test]
    #[should_panic(expected = "watermarks")]
    fn inverted_watermarks_rejected() {
        let _ = MemoryGovernor::new(
            GovernorConfig { low_watermark: 0.9, high_watermark: 0.5, ..Default::default() },
            0,
        );
    }
}
