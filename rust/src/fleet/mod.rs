//! The fleet serving layer: many concurrent QLR-CL learners per host.
//!
//! The paper's economics make this layer almost free: quantized latent
//! replays shrink per-learner state to a few hundred kilobytes (8-bit LRs
//! are ~lossless at 4x compression, §III-C), and the frozen/adaptive
//! split (Pellegrini et al., PAPERS.md) means the expensive part of the
//! network — frozen weights, PTQ calibration, the kernel engine — is
//! **identical for every learner** and shared via `Arc`. What remains per
//! tenant is an adaptive head, a replay buffer, a metrics block and a
//! deterministic RNG stream.
//!
//! Module map:
//!
//! - [`server`] — [`FleetServer`]: tenant slots, admission control,
//!   pool-resident serving workers (tasks on the process-wide
//!   [`crate::exec::ExecPool`]), cross-session batched inference, and
//!   background eval sweeps ([`EvalHandle`]);
//! - [`tenant`] — [`Tenant`]: per-learner state; bit-for-bit parity with
//!   the single-session `Session` at N=1;
//! - [`governor`] — [`MemoryGovernor`]: one global byte budget (64 MB by
//!   default, per the paper), run as a three-tier replay hierarchy —
//!   **hot** 8-bit in RAM, **warm** 7-bit in RAM (in-place demotion),
//!   **cold** spilled to disk — with a watermark-hysteresis promotion
//!   ladder (unspill + 7→8-bit re-widen) when pressure clears;
//! - [`snapshot`] — the versioned, checksummed binary tenant-snapshot
//!   format the cold tier stores (bit-exact spill→restore);
//! - [`ingress`] — [`Bounded`]: the bounded MPSC event queue workers
//!   drain in batches (the hook for cross-tenant frozen coalescing);
//! - [`faults`] — [`FaultPlan`]: seeded, byte-for-byte replayable fault
//!   injection (spill I/O errors, torn/corrupt writes, stalls, budget
//!   shocks) behind the [`SpillIo`] trait; drives the chaos suite
//!   (`rust/tests/chaos.rs`) and `tinycl fleet --fault-plan <seed>`;
//! - [`api`] — the redesigned client surface: [`FleetConfigBuilder`],
//!   the unified [`FleetError`], the [`FleetApi`] trait shared by the
//!   in-process [`LocalClient`] and the network
//!   [`crate::net::client::RemoteClient`];
//! - [`shard`] — tenant routing across many shard processes:
//!   [`ShardRouter`] (pure tenant→shard hash + migration pins) and
//!   [`FleetClient`] (multi-shard [`FleetApi`] with live snapshot
//!   migration and pressure-driven rebalancing over
//!   [`crate::net::frame`]).
//!
//! Entry points: `tinycl fleet` (CLI demo), `tinycl shard` /
//! `tinycl shard-client` (networked shards over loopback),
//! `examples/fleet_serving.rs` (64+ tenants under a 64 MB governor, plus
//! the spill-tier capacity demo), `rust/tests/fleet.rs` +
//! `rust/tests/snapshot.rs` + `rust/tests/shard.rs` (determinism, N=1
//! parity, spill/restore and migration bit-parity, concurrency stress).

pub mod api;
pub mod faults;
pub mod governor;
pub mod ingress;
pub mod server;
pub mod shard;
pub mod snapshot;
pub mod supervisor;
pub mod tenant;
pub mod traffic;

pub use api::{
    submit_with_backoff, FleetApi, FleetConfigBuilder, FleetError, LocalClient, SubmitOutcome,
};
pub use faults::{
    DirectIo, FaultPlan, FaultSpec, FaultyIo, NetFault, ReadFault, RetryPolicy, Shock, SpillIo,
    WriteFault,
};
pub use governor::{
    GovernorAction, GovernorConfig, GovernorTally, MemoryGovernor, ReliefMode, SpilledFootprint,
    TenantFootprint, DEFAULT_BUDGET_BYTES,
};
pub use ingress::Bounded;
pub use server::{
    Admission, EvalHandle, EvalOutcome, FleetConfig, FleetEvent, FleetReport, FleetServer,
    InferRequest, RebalanceOutcome, Rejected, ServiceLevel, ServingSession, Submitted,
    EVAL_SAMPLE_STRIDE,
};
pub use shard::{shard_of, FleetClient, Pending, ShardRouter, HEARTBEAT_MISSES};
pub use supervisor::{ShardSupervisor, SupervisorConfig, SupervisorReport};
pub use tenant::{Tenant, TenantConfig, TenantId, TenantMetrics, TenantSnapshot};
