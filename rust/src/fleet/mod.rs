//! The fleet serving layer: many concurrent QLR-CL learners per host.
//!
//! The paper's economics make this layer almost free: quantized latent
//! replays shrink per-learner state to a few hundred kilobytes (8-bit LRs
//! are ~lossless at 4x compression, §III-C), and the frozen/adaptive
//! split (Pellegrini et al., PAPERS.md) means the expensive part of the
//! network — frozen weights, PTQ calibration, the kernel engine — is
//! **identical for every learner** and shared via `Arc`. What remains per
//! tenant is an adaptive head, a replay buffer, a metrics block and a
//! deterministic RNG stream.
//!
//! Module map:
//!
//! - [`server`] — [`FleetServer`]: tenant slots, admission control, the
//!   worker pool, cross-session batched inference;
//! - [`tenant`] — [`Tenant`]: per-learner state; bit-for-bit parity with
//!   the single-session `Session` at N=1;
//! - [`governor`] — [`MemoryGovernor`]: one global byte budget (64 MB by
//!   default, per the paper), relieved by in-place 8→7-bit replay
//!   demotion and slot shrinking of the coldest tenants;
//! - [`ingress`] — [`Bounded`]: the bounded MPSC event queue workers
//!   drain in batches (the hook for cross-tenant frozen coalescing).
//!
//! Entry points: `tinycl fleet` (CLI demo), `examples/fleet_serving.rs`
//! (64+ tenants under a 64 MB governor), `rust/tests/fleet.rs`
//! (determinism, N=1 parity, concurrency stress).

pub mod governor;
pub mod ingress;
pub mod server;
pub mod tenant;
pub mod traffic;

pub use governor::{
    GovernorAction, GovernorConfig, MemoryGovernor, TenantFootprint, DEFAULT_BUDGET_BYTES,
};
pub use ingress::Bounded;
pub use server::{FleetConfig, FleetEvent, FleetReport, FleetServer, InferRequest};
pub use tenant::{Tenant, TenantConfig, TenantId, TenantMetrics, TenantSnapshot};
