//! Deterministic fault injection for the fleet — the answer to "what
//! happens when the disk lies."
//!
//! A [`FaultPlan`] is a *seeded schedule* of failures: spill-write
//! faults (EIO, ENOSPC, torn partial writes, silently corrupted bytes),
//! restore-read faults, slow-worker stalls, sudden memory-budget shocks
//! and ingress-burst sizes. Every decision is a **pure function of
//! `(seed, domain, operation index, attempt)`** — a fresh [`Rng`] is
//! derived per decision rather than consumed from a shared stream — so
//! the schedule is replayable byte-for-byte no matter how threads
//! interleave: operation *k* of a domain sees the same fault under any
//! worker count. The only mutable state is per-domain operation
//! counters (atomics), which exist so call sites don't have to thread
//! indices around.
//!
//! Two canonical plans:
//!
//! - [`FaultPlan::seeded`] — the chaotic mix, including fail streaks
//!   long enough to exhaust the retry budget and *persistent* silent
//!   write corruption (detected only at restore, exercising quarantine
//!   + [`GovernorAction::Degrade`](super::governor::GovernorAction));
//! - [`FaultPlan::recovering`] — transient-only: every fail streak is
//!   strictly shorter than the default retry budget and writes are
//!   never corrupted, so retried I/O always succeeds and a run under
//!   this plan is **bit-identical** to a faults-disabled run (the chaos
//!   suite's determinism arm).
//!
//! [`FaultPlan::none`] is the static no-op: a `None` behind one
//! pointer-sized `Option`, so the disabled hooks cost a branch and no
//! RNG work — the production path stays byte-identical.
//!
//! The spill I/O seam is the [`SpillIo`] trait: [`DirectIo`] delegates
//! straight to the snapshot codec, [`FaultyIo`] wraps it with a plan.
//! The server owns the bounded retry-with-backoff loop around it.

use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::snapshot;
use super::tenant::TenantSnapshot;
use crate::util::rng::Rng;

/// Decision-stream domain tags (xor'd into the per-decision seed so the
/// write/read/stall/burst schedules are independent).
const DOMAIN_WRITE: u64 = 0x57_52_49_54_45; // "WRITE"
const DOMAIN_READ: u64 = 0x52_45_41_44; // "READ"
const DOMAIN_STALL: u64 = 0x53_54_41_4C_4C; // "STALL"
const DOMAIN_BURST: u64 = 0x42_55_52_53_54; // "BURST"
// network fault domains (PR 10): the same pure-(seed, domain, op,
// attempt) discipline extended across the wire
const DOMAIN_CONNECT: u64 = 0x43_4F_4E_4E; // "CONN"
const DOMAIN_FRAME_WRITE: u64 = 0x46_57_52_49_54; // "FWRIT"
const DOMAIN_FRAME_READ: u64 = 0x46_52_45_41_44; // "FREAD"
const DOMAIN_NET_STALL: u64 = 0x4E_53_54_41_4C; // "NSTAL"

const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// One decision generator: fresh per `(seed, domain, op)`, never shared,
/// so decisions cannot depend on thread interleaving.
fn decision_rng(seed: u64, domain: u64, op: u64) -> Rng {
    Rng::new(seed ^ domain.wrapping_mul(GOLDEN) ^ op.wrapping_mul(0xD1B5_4A32_D192_ED03))
}

/// What to do to one spill-write attempt.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WriteFault {
    /// The write errors out before publishing anything (transient).
    Error(&'static str),
    /// A torn write: only this fraction of the bytes reach the `.tmp`
    /// sibling and the rename never happens — the previously published
    /// snapshot (if any) stays intact, which is exactly what the
    /// write-tmp + fsync + rename protocol must guarantee. Transient.
    Torn(f64),
    /// The write "succeeds" but the published bytes are silently
    /// damaged — a lying disk. Persistent: only a later restore can
    /// discover it (checksum), triggering quarantine + degrade.
    Corrupt,
}

/// What to do to one restore-read attempt. Both kinds are transient (a
/// retry re-reads the real file); *persistent* read corruption comes
/// from [`WriteFault::Corrupt`] having damaged the file itself.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadFault {
    Error(&'static str),
    /// Flip a byte of the read buffer in memory before decoding.
    Corrupt,
}

/// What to do to one network attempt (a connect, a frame send, or a
/// frame receive). Injected under [`crate::net`]'s io shim, never in
/// the protocol codec itself.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NetFault {
    /// The connection drops: the attempt errors out and the stream is
    /// unusable afterwards (the client must reconnect). Transient.
    Drop(&'static str),
    /// A torn frame: the length prefix promises the full payload but
    /// only this fraction of the bytes is sent before the stream is
    /// shut down — and the *send call reports success*. The failure
    /// surfaces at the peer (mid-frame EOF) and at the reply read.
    Torn(f64),
    /// The attempt is delayed by this long, then proceeds normally.
    Stall(Duration),
}

/// A scheduled budget shock: once `after_events` events have been
/// applied fleet-wide, the governor budget is multiplied by
/// `budget_factor` (shrink < 1.0 forces relief; > 1.0 models recovered
/// headroom).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Shock {
    pub after_events: u64,
    pub budget_factor: f64,
}

/// Tunable fault mix — the raw material behind the canonical plans.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    pub seed: u64,
    /// probability a spill-write operation is faulty at all
    pub write_fault_p: f64,
    /// max consecutive failing attempts per faulty write op
    pub write_streak_max: u32,
    /// allow silent (persistent) write corruption
    pub corrupt_writes: bool,
    /// allow torn partial writes
    pub torn_writes: bool,
    /// probability a restore-read operation is faulty
    pub read_fault_p: f64,
    /// max consecutive failing attempts per faulty read op
    pub read_streak_max: u32,
    /// probability one worker batch stalls
    pub stall_p: f64,
    /// how long a stalled worker sleeps
    pub stall: Duration,
    /// budget shocks, ascending by `after_events`
    pub shocks: Vec<Shock>,
    /// max events per ingress burst (for harness-driven submission)
    pub burst_max: usize,
    /// probability one connect operation is faulty
    pub connect_fault_p: f64,
    /// max consecutive failing attempts per faulty connect op
    pub connect_streak_max: u32,
    /// probability one frame send/receive operation is faulty
    pub frame_fault_p: f64,
    /// max consecutive failing attempts per faulty frame op
    pub frame_streak_max: u32,
    /// allow torn frames (truncated payload that "succeeds")
    pub torn_frames: bool,
    /// probability one frame operation stalls before proceeding
    pub net_stall_p: f64,
    /// how long a stalled frame operation sleeps
    pub net_stall: Duration,
    /// scripted shard death: the serving process exits after this many
    /// frames served (claimed once; `None` = never)
    pub crash_after_frames: Option<u64>,
}

impl Default for FaultSpec {
    /// The all-quiet spec: every probability zero, every streak one,
    /// no shocks, no scripted crash — the base the presets and tests
    /// override field-by-field.
    fn default() -> FaultSpec {
        FaultSpec {
            seed: 0,
            write_fault_p: 0.0,
            write_streak_max: 1,
            corrupt_writes: false,
            torn_writes: false,
            read_fault_p: 0.0,
            read_streak_max: 1,
            stall_p: 0.0,
            stall: Duration::ZERO,
            shocks: vec![],
            burst_max: 1,
            connect_fault_p: 0.0,
            connect_streak_max: 1,
            frame_fault_p: 0.0,
            frame_streak_max: 1,
            torn_frames: false,
            net_stall_p: 0.0,
            net_stall: Duration::ZERO,
            crash_after_frames: None,
        }
    }
}

struct Inner {
    spec: FaultSpec,
    stall_ops: AtomicU64,
    burst_ops: AtomicU64,
    shock_idx: AtomicUsize,
    crash_claimed: AtomicUsize,
}

impl std::fmt::Debug for Inner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan").field("spec", &self.spec).finish()
    }
}

/// A seeded, replayable fault schedule (or the static no-op plan).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    inner: Option<Arc<Inner>>,
}

impl FaultPlan {
    /// The static no-op plan: nothing is ever injected, every hook is a
    /// single branch on a `None`.
    pub fn none() -> FaultPlan {
        FaultPlan { inner: None }
    }

    /// The full chaotic mix: fail streaks that can exhaust the default
    /// retry budget, torn writes, silent persistent corruption, stalls,
    /// budget shocks. Survival — not transparency — is the contract.
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan::from_spec(FaultSpec {
            seed,
            write_fault_p: 0.40,
            write_streak_max: 6,
            corrupt_writes: true,
            torn_writes: true,
            read_fault_p: 0.35,
            read_streak_max: 6,
            stall_p: 0.15,
            stall: Duration::from_millis(2),
            shocks: vec![
                Shock { after_events: 5, budget_factor: 0.7 },
                Shock { after_events: 12, budget_factor: 1.25 },
            ],
            burst_max: 6,
            ..FaultSpec::default()
        })
    }

    /// Transient-only plan: every fail streak is strictly shorter than
    /// the default retry budget ([`RetryPolicy::default`] = 4 attempts)
    /// and writes are never corrupted, so every spill/restore
    /// eventually succeeds with the exact intended bytes. A run under
    /// this plan must be bit-identical to a faults-disabled run.
    pub fn recovering(seed: u64) -> FaultPlan {
        FaultPlan::from_spec(FaultSpec {
            seed,
            write_fault_p: 0.45,
            write_streak_max: 2, // < RetryPolicy::default().attempts
            corrupt_writes: false,
            torn_writes: true,
            read_fault_p: 0.35,
            read_streak_max: 2,
            stall_p: 0.10,
            stall: Duration::from_millis(1),
            shocks: vec![Shock { after_events: 6, budget_factor: 0.8 }],
            burst_max: 4,
            ..FaultSpec::default()
        })
    }

    /// The chaotic *network* mix: connect refusals, dropped
    /// connections, torn frames (truncated payload that "succeeds"),
    /// and seeded stalls, with fail streaks long enough to exhaust a
    /// default retry budget. Disk I/O is left clean so every observed
    /// recovery is attributable to the wire. Survival is the contract;
    /// exactly-once application holds via the dedup window.
    pub fn net_seeded(seed: u64) -> FaultPlan {
        FaultPlan::from_spec(FaultSpec {
            seed,
            connect_fault_p: 0.25,
            connect_streak_max: 6,
            frame_fault_p: 0.30,
            frame_streak_max: 6,
            torn_frames: true,
            net_stall_p: 0.10,
            net_stall: Duration::from_millis(1),
            ..FaultSpec::default()
        })
    }

    /// Transient-only network plan: every connect/frame fail streak is
    /// strictly shorter than the default retry budget and there is no
    /// scripted crash, so every retried request eventually lands (or is
    /// acknowledged as a duplicate) — a run under this plan must be
    /// **bit-identical** to a [`FaultPlan::none`] run.
    pub fn net_recovering(seed: u64) -> FaultPlan {
        FaultPlan::from_spec(FaultSpec {
            seed,
            connect_fault_p: 0.30,
            connect_streak_max: 2, // < RetryPolicy::default().attempts
            frame_fault_p: 0.35,
            frame_streak_max: 2,
            torn_frames: true,
            net_stall_p: 0.08,
            net_stall: Duration::from_micros(200),
            ..FaultSpec::default()
        })
    }

    /// This plan plus a scripted shard death after `after_frames`
    /// served frames (claimed once — the supervisor drill's trigger).
    pub fn with_shard_crash(&self, after_frames: u64) -> FaultPlan {
        let mut spec = match self.inner.as_deref() {
            Some(i) => i.spec.clone(),
            None => FaultSpec::default(),
        };
        spec.crash_after_frames = Some(after_frames);
        FaultPlan::from_spec(spec)
    }

    pub fn from_spec(spec: FaultSpec) -> FaultPlan {
        FaultPlan {
            inner: Some(Arc::new(Inner {
                spec,
                stall_ops: AtomicU64::new(0),
                burst_ops: AtomicU64::new(0),
                shock_idx: AtomicUsize::new(0),
                crash_claimed: AtomicUsize::new(0),
            })),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    pub fn seed(&self) -> Option<u64> {
        self.inner.as_deref().map(|i| i.spec.seed)
    }

    /// Fault decision for write operation `op`, attempt `attempt`
    /// (0-based). Pure in `(seed, op, attempt)`.
    pub fn write_fault(&self, op: u64, attempt: u32) -> Option<WriteFault> {
        let s = &self.inner.as_deref()?.spec;
        let mut rng = decision_rng(s.seed, DOMAIN_WRITE, op);
        let hit = rng.f64() < s.write_fault_p;
        let streak = 1 + rng.below(s.write_streak_max.max(1) as usize) as u32;
        let kind = rng.f64();
        let torn_frac = rng.range_f64(0.05, 0.95);
        if !hit {
            return None;
        }
        if s.corrupt_writes && kind < 0.25 {
            // persistent lying-disk corruption happens on the first
            // attempt and then "succeeds" — there is nothing to retry
            return (attempt == 0).then_some(WriteFault::Corrupt);
        }
        if attempt >= streak {
            return None; // the streak ended; this attempt goes through
        }
        Some(if s.torn_writes && kind < 0.55 {
            WriteFault::Torn(torn_frac)
        } else if kind < 0.80 {
            WriteFault::Error("EIO: injected write failure")
        } else {
            WriteFault::Error("ENOSPC: injected device full")
        })
    }

    /// Fault decision for read operation `op`, attempt `attempt`.
    pub fn read_fault(&self, op: u64, attempt: u32) -> Option<ReadFault> {
        let s = &self.inner.as_deref()?.spec;
        let mut rng = decision_rng(s.seed, DOMAIN_READ, op);
        let hit = rng.f64() < s.read_fault_p;
        let streak = 1 + rng.below(s.read_streak_max.max(1) as usize) as u32;
        let kind = rng.f64();
        if !hit || attempt >= streak {
            return None;
        }
        Some(if kind < 0.5 {
            ReadFault::Error("EIO: injected read failure")
        } else {
            ReadFault::Corrupt
        })
    }

    /// Slow-worker hook: should the calling worker stall before its next
    /// batch, and for how long?
    pub fn stall(&self) -> Option<Duration> {
        let inner = self.inner.as_deref()?;
        let op = inner.stall_ops.fetch_add(1, Ordering::Relaxed);
        let mut rng = decision_rng(inner.spec.seed, DOMAIN_STALL, op);
        (rng.f64() < inner.spec.stall_p).then_some(inner.spec.stall)
    }

    /// Budget-shock hook: once `events_done` crosses the next scheduled
    /// shock, claim it (exactly one caller wins) and return its factor.
    pub fn take_shock(&self, events_done: u64) -> Option<f64> {
        let inner = self.inner.as_deref()?;
        loop {
            let idx = inner.shock_idx.load(Ordering::Relaxed);
            let shock = inner.spec.shocks.get(idx)?;
            if events_done < shock.after_events {
                return None;
            }
            if inner
                .shock_idx
                .compare_exchange(idx, idx + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return Some(shock.budget_factor);
            }
        }
    }

    /// Ingress-burst size for the harness's next submission wave
    /// (`None` when faults are disabled — submit however you like).
    pub fn burst(&self) -> Option<usize> {
        let inner = self.inner.as_deref()?;
        let op = inner.burst_ops.fetch_add(1, Ordering::Relaxed);
        let mut rng = decision_rng(inner.spec.seed, DOMAIN_BURST, op);
        Some(1 + rng.below(inner.spec.burst_max.max(1)))
    }

    // ---- network decisions (all pure in (seed, op, attempt); the
    // caller supplies the logical operation index so the schedule is
    // independent of thread interleaving and wall clock) ----

    /// Fault decision for connect operation `op`, attempt `attempt`.
    pub fn connect_fault(&self, op: u64, attempt: u32) -> Option<NetFault> {
        let s = &self.inner.as_deref()?.spec;
        let mut rng = decision_rng(s.seed, DOMAIN_CONNECT, op);
        let hit = rng.f64() < s.connect_fault_p;
        let streak = 1 + rng.below(s.connect_streak_max.max(1) as usize) as u32;
        if !hit || attempt >= streak {
            return None;
        }
        Some(NetFault::Drop("ECONNREFUSED: injected connect failure"))
    }

    /// Fault decision for frame-send operation `op`, attempt `attempt`.
    pub fn frame_write_fault(&self, op: u64, attempt: u32) -> Option<NetFault> {
        let s = &self.inner.as_deref()?.spec;
        let mut rng = decision_rng(s.seed, DOMAIN_FRAME_WRITE, op);
        let hit = rng.f64() < s.frame_fault_p;
        let streak = 1 + rng.below(s.frame_streak_max.max(1) as usize) as u32;
        let kind = rng.f64();
        let frac = rng.range_f64(0.05, 0.95);
        if !hit || attempt >= streak {
            return None;
        }
        Some(if s.torn_frames && kind < 0.45 {
            NetFault::Torn(frac)
        } else {
            NetFault::Drop("ECONNRESET: injected send failure")
        })
    }

    /// Fault decision for frame-receive operation `op`, attempt
    /// `attempt` — the peer's reply is lost mid-read.
    pub fn frame_read_fault(&self, op: u64, attempt: u32) -> Option<NetFault> {
        let s = &self.inner.as_deref()?.spec;
        let mut rng = decision_rng(s.seed, DOMAIN_FRAME_READ, op);
        let hit = rng.f64() < s.frame_fault_p;
        let streak = 1 + rng.below(s.frame_streak_max.max(1) as usize) as u32;
        if !hit || attempt >= streak {
            return None;
        }
        Some(NetFault::Drop("ECONNRESET: injected receive failure"))
    }

    /// Seeded network stall for frame operation `op` (pure in op — the
    /// frame is delayed, then proceeds).
    pub fn net_stall(&self, op: u64) -> Option<Duration> {
        let s = &self.inner.as_deref()?.spec;
        let mut rng = decision_rng(s.seed, DOMAIN_NET_STALL, op);
        (rng.f64() < s.net_stall_p).then_some(s.net_stall)
    }

    /// Scripted shard death: `true` exactly once, when `frames_served`
    /// reaches the scripted count. The serving process is expected to
    /// exit immediately — the supervisor drill's trigger.
    pub fn crash_due(&self, frames_served: u64) -> bool {
        let Some(inner) = self.inner.as_deref() else { return false };
        let Some(n) = inner.spec.crash_after_frames else { return false };
        frames_served >= n
            && inner
                .crash_claimed
                .compare_exchange(0, 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
    }
}

/// Bounded retry-with-exponential-backoff policy for spill/restore I/O.
/// The *decisions* never read a clock — backoff is a pure function of
/// the attempt index — so fault schedules stay replayable; the sleep
/// merely spaces real I/O attempts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// total attempts per logical operation (>= 1)
    pub attempts: u32,
    /// backoff before retry k is `base * 2^k`
    pub base: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { attempts: 4, base: Duration::from_millis(1) }
    }
}

impl RetryPolicy {
    /// Backoff to sleep before retry attempt `attempt` (1-based: the
    /// first retry sleeps `base`).
    pub fn backoff(&self, attempt: u32) -> Duration {
        self.base.saturating_mul(1u32 << attempt.saturating_sub(1).min(10))
    }
}

/// The thin seam all cold-tier I/O flows through. One *attempt* per
/// call; the server's retry loop supplies a stable operation id and the
/// attempt index so a fault plan can schedule per-operation streaks.
pub trait SpillIo: Send + Sync {
    fn write_snapshot(
        &self,
        path: &Path,
        snap: &TenantSnapshot,
        op: u64,
        attempt: u32,
    ) -> Result<usize>;

    fn read_snapshot(&self, path: &Path, op: u64, attempt: u32) -> Result<TenantSnapshot>;
}

/// Production I/O: straight to the snapshot codec, ignoring the
/// schedule coordinates.
pub struct DirectIo;

impl SpillIo for DirectIo {
    fn write_snapshot(
        &self,
        path: &Path,
        snap: &TenantSnapshot,
        _op: u64,
        _attempt: u32,
    ) -> Result<usize> {
        snapshot::write_file(path, snap)
    }

    fn read_snapshot(&self, path: &Path, _op: u64, _attempt: u32) -> Result<TenantSnapshot> {
        snapshot::read_file(path)
    }
}

/// Fault-injecting I/O: consults the plan before every attempt.
pub struct FaultyIo {
    plan: FaultPlan,
}

impl FaultyIo {
    pub fn new(plan: FaultPlan) -> FaultyIo {
        FaultyIo { plan }
    }
}

impl SpillIo for FaultyIo {
    fn write_snapshot(
        &self,
        path: &Path,
        snap: &TenantSnapshot,
        op: u64,
        attempt: u32,
    ) -> Result<usize> {
        match self.plan.write_fault(op, attempt) {
            None => snapshot::write_file(path, snap),
            Some(WriteFault::Error(msg)) => {
                bail!("{msg} ({}, op {op} attempt {attempt})", path.display())
            }
            Some(WriteFault::Torn(frac)) => {
                // a crash mid-write: some prefix of the bytes reaches the
                // tmp sibling, the rename never runs, the caller sees an
                // error. The previously published file must survive.
                let bytes = snapshot::encode(snap);
                let n = ((bytes.len() as f64 * frac) as usize).min(bytes.len());
                let tmp = path.with_extension("tmp");
                std::fs::write(&tmp, &bytes[..n])
                    .with_context(|| format!("writing torn tmp {}", tmp.display()))?;
                bail!(
                    "injected torn write: {n}/{} bytes reached {} (op {op} attempt {attempt})",
                    bytes.len(),
                    tmp.display()
                )
            }
            Some(WriteFault::Corrupt) => {
                // the lying disk: publish durably, damage silently
                let mut bytes = snapshot::encode(snap);
                let i = (op as usize).wrapping_mul(131) % bytes.len();
                bytes[i] ^= 0x40;
                snapshot::write_bytes(path, &bytes)?;
                Ok(bytes.len())
            }
        }
    }

    fn read_snapshot(&self, path: &Path, op: u64, attempt: u32) -> Result<TenantSnapshot> {
        match self.plan.read_fault(op, attempt) {
            None => snapshot::read_file(path),
            Some(ReadFault::Error(msg)) => {
                bail!("{msg} ({}, op {op} attempt {attempt})", path.display())
            }
            Some(ReadFault::Corrupt) => {
                let mut bytes = std::fs::read(path)
                    .with_context(|| format!("reading tenant snapshot {}", path.display()))?;
                if !bytes.is_empty() {
                    let i = (op as usize).wrapping_mul(197) % bytes.len();
                    bytes[i] ^= 0x01;
                }
                snapshot::decode(&bytes)
                    .with_context(|| format!("decoding tenant snapshot {}", path.display()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::replay::ReplayBuffer;
    use crate::coordinator::trainer::CLConfig;
    use crate::fleet::tenant::TenantMetrics;
    use crate::runtime::{ParamState, TensorF32};

    fn sample_snapshot() -> TenantSnapshot {
        let elems = 8;
        let mut rng = Rng::new(3);
        let mut replay = ReplayBuffer::new_packed(4, elems, 8, 1.0);
        let latents: Vec<f32> = (0..3 * elems).map(|i| (i % 11) as f32 * 0.07).collect();
        let labels: Vec<i32> = vec![0, 1, 2];
        replay.init_fill(&latents, &labels, &mut rng);
        TenantSnapshot {
            cfg: CLConfig {
                l: 15,
                n_lr: 4,
                lr_bits: 8,
                int8_frozen: true,
                lr: 0.1,
                epochs: 1,
                seed: 9,
            },
            params: ParamState::from_tensors(
                vec!["b".into(), "w".into()],
                vec![
                    TensorF32::new(vec![2], vec![0.25, -1.5]),
                    TensorF32::new(vec![2, 2], vec![1., 2., 3., 4.]),
                ],
            ),
            replay,
            rng,
            metrics: TenantMetrics::default(),
            next_seq: 5,
            parked: Vec::new(),
        }
    }

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("tinycl_faults_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).expect("mkdir");
        dir
    }

    #[test]
    fn schedule_is_replayable_across_instances() {
        for seed in [7u64, 19, 101] {
            let a = FaultPlan::seeded(seed);
            let b = FaultPlan::seeded(seed);
            for op in 0..512u64 {
                for attempt in 0..8u32 {
                    assert_eq!(a.write_fault(op, attempt), b.write_fault(op, attempt));
                    assert_eq!(a.read_fault(op, attempt), b.read_fault(op, attempt));
                }
            }
        }
    }

    #[test]
    fn disabled_plan_injects_nothing() {
        let p = FaultPlan::none();
        assert!(!p.is_enabled());
        for op in 0..64 {
            assert_eq!(p.write_fault(op, 0), None);
            assert_eq!(p.read_fault(op, 0), None);
        }
        assert_eq!(p.stall(), None);
        assert_eq!(p.take_shock(u64::MAX), None);
        assert_eq!(p.burst(), None);
        for op in 0..64 {
            assert_eq!(p.connect_fault(op, 0), None);
            assert_eq!(p.frame_write_fault(op, 0), None);
            assert_eq!(p.frame_read_fault(op, 0), None);
            assert_eq!(p.net_stall(op), None);
        }
        assert!(!p.crash_due(u64::MAX));
    }

    #[test]
    fn net_schedule_is_replayable_across_instances() {
        for seed in [7u64, 19, 101] {
            let a = FaultPlan::net_seeded(seed);
            let b = FaultPlan::net_seeded(seed);
            for op in 0..512u64 {
                for attempt in 0..8u32 {
                    assert_eq!(a.connect_fault(op, attempt), b.connect_fault(op, attempt));
                    assert_eq!(
                        a.frame_write_fault(op, attempt),
                        b.frame_write_fault(op, attempt)
                    );
                    assert_eq!(
                        a.frame_read_fault(op, attempt),
                        b.frame_read_fault(op, attempt)
                    );
                }
                assert_eq!(a.net_stall(op), b.net_stall(op));
            }
        }
    }

    #[test]
    fn net_chaotic_plan_exercises_every_net_fault_kind() {
        let p = FaultPlan::net_seeded(42);
        let (mut conns, mut torn, mut drops, mut reads, mut stalls) = (0, 0, 0, 0, 0);
        for op in 0..4000u64 {
            if p.connect_fault(op, 0).is_some() {
                conns += 1;
            }
            match p.frame_write_fault(op, 0) {
                Some(NetFault::Torn(f)) => {
                    assert!((0.05..0.95).contains(&f));
                    torn += 1;
                }
                Some(NetFault::Drop(_)) => drops += 1,
                Some(NetFault::Stall(_)) => unreachable!("writes never stall via this hook"),
                None => {}
            }
            if p.frame_read_fault(op, 0).is_some() {
                reads += 1;
            }
            if p.net_stall(op).is_some() {
                stalls += 1;
            }
        }
        assert!(conns > 0 && torn > 0 && drops > 0 && reads > 0 && stalls > 0);
    }

    #[test]
    fn net_recovering_plan_recovers_within_the_default_retry_budget() {
        let retry = RetryPolicy::default();
        for seed in [1u64, 7, 19, 101, 555] {
            let p = FaultPlan::net_recovering(seed);
            for op in 0..2000u64 {
                assert_eq!(
                    p.connect_fault(op, retry.attempts - 1),
                    None,
                    "connect op {op} still failing at the last attempt"
                );
                assert_eq!(
                    p.frame_write_fault(op, retry.attempts - 1),
                    None,
                    "frame-send op {op} still failing at the last attempt"
                );
                assert_eq!(
                    p.frame_read_fault(op, retry.attempts - 1),
                    None,
                    "frame-recv op {op} still failing at the last attempt"
                );
            }
            assert!(!p.crash_due(u64::MAX), "recovering plans never crash the shard");
        }
    }

    #[test]
    fn scripted_crash_fires_once_at_its_frame_count() {
        let p = FaultPlan::net_recovering(3).with_shard_crash(5);
        assert!(!p.crash_due(0));
        assert!(!p.crash_due(4));
        assert!(p.crash_due(5), "the scripted frame count must trigger");
        assert!(!p.crash_due(6), "the crash is claimed exactly once");
        // deriving from the no-op plan scripts ONLY the crash
        let bare = FaultPlan::none().with_shard_crash(2);
        assert_eq!(bare.frame_write_fault(0, 0), None);
        assert!(bare.crash_due(2));
    }

    #[test]
    fn chaotic_plan_exercises_every_fault_kind() {
        // statistically certain for ANY seed at these probabilities over
        // 4000 ops — this pins the mix, not one seed's lottery
        let p = FaultPlan::seeded(42);
        let (mut errs, mut torn, mut corrupt, mut reads) = (0, 0, 0, 0);
        for op in 0..4000u64 {
            match p.write_fault(op, 0) {
                Some(WriteFault::Error(_)) => errs += 1,
                Some(WriteFault::Torn(f)) => {
                    assert!((0.05..0.95).contains(&f));
                    torn += 1;
                }
                Some(WriteFault::Corrupt) => corrupt += 1,
                None => {}
            }
            if p.read_fault(op, 0).is_some() {
                reads += 1;
            }
        }
        assert!(errs > 0 && torn > 0 && corrupt > 0 && reads > 0);
    }

    #[test]
    fn recovering_plan_always_recovers_within_the_default_retry_budget() {
        let retry = RetryPolicy::default();
        for seed in [1u64, 7, 19, 101, 555] {
            let p = FaultPlan::recovering(seed);
            for op in 0..2000u64 {
                assert_ne!(
                    p.write_fault(op, retry.attempts - 1),
                    Some(WriteFault::Corrupt),
                    "recovering plans never corrupt"
                );
                assert_eq!(
                    p.write_fault(op, retry.attempts - 1),
                    None,
                    "write op {op} still failing at the last attempt"
                );
                assert_eq!(
                    p.read_fault(op, retry.attempts - 1),
                    None,
                    "read op {op} still failing at the last attempt"
                );
            }
        }
    }

    #[test]
    fn shocks_fire_once_in_order() {
        let p = FaultPlan::seeded(5);
        assert_eq!(p.take_shock(0), None, "no shock before its event count");
        let first = p.take_shock(100).expect("first shock due");
        let second = p.take_shock(100).expect("second shock due");
        assert_eq!((first, second), (0.7, 1.25));
        assert_eq!(p.take_shock(u64::MAX), None, "schedule exhausted");
    }

    #[test]
    fn backoff_is_exponential_and_capped() {
        let r = RetryPolicy { attempts: 8, base: Duration::from_millis(1) };
        assert_eq!(r.backoff(1), Duration::from_millis(1));
        assert_eq!(r.backoff(2), Duration::from_millis(2));
        assert_eq!(r.backoff(3), Duration::from_millis(4));
        assert!(r.backoff(60) <= Duration::from_millis(1024));
    }

    #[test]
    fn faulty_io_torn_write_never_shadows_the_published_file() {
        let dir = tmp_dir("torn");
        let path = dir.join("tenant_0.tcsn");
        let snap = sample_snapshot();
        // publish a good snapshot first via the direct path
        let good = DirectIo.write_snapshot(&path, &snap, 0, 0).expect("direct write");
        assert!(good > 0);
        let published = std::fs::read(&path).expect("published bytes");
        // find a torn-write decision and run it
        let plan = FaultPlan::seeded(11);
        let io = FaultyIo::new(plan.clone());
        let torn_op = (0..10_000u64)
            .find(|&op| matches!(plan.write_fault(op, 0), Some(WriteFault::Torn(_))))
            .expect("a chaotic plan torn-write op");
        let err = io.write_snapshot(&path, &snap, torn_op, 0).unwrap_err();
        assert!(format!("{err:#}").contains("torn write"), "{err:#}");
        // the published file is byte-identical; only the tmp is damaged
        assert_eq!(std::fs::read(&path).expect("still readable"), published);
        let back = DirectIo.read_snapshot(&path, 0, 0).expect("decode");
        assert_eq!(snapshot::encode(&back), published);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn faulty_io_corrupt_write_is_caught_at_restore() {
        let dir = tmp_dir("corrupt");
        let path = dir.join("tenant_0.tcsn");
        let snap = sample_snapshot();
        let plan = FaultPlan::seeded(13);
        let io = FaultyIo::new(plan.clone());
        let bad_op = (0..10_000u64)
            .find(|&op| plan.write_fault(op, 0) == Some(WriteFault::Corrupt))
            .expect("a chaotic plan corrupt-write op");
        let n = io.write_snapshot(&path, &snap, bad_op, 0).expect("silently 'succeeds'");
        assert!(n > 0);
        // the lie surfaces only when something reads the file back
        assert!(DirectIo.read_snapshot(&path, 0, 0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn faulty_io_read_corruption_is_transient() {
        let dir = tmp_dir("readc");
        let path = dir.join("tenant_0.tcsn");
        let snap = sample_snapshot();
        DirectIo.write_snapshot(&path, &snap, 0, 0).expect("write");
        let plan = FaultPlan::seeded(17);
        let io = FaultyIo::new(plan.clone());
        let bad_op = (0..10_000u64)
            .find(|&op| plan.read_fault(op, 0) == Some(ReadFault::Corrupt))
            .expect("a chaotic plan corrupt-read op");
        assert!(io.read_snapshot(&path, bad_op, 0).is_err(), "in-memory flip must fail decode");
        // the file itself was never touched: a clean attempt succeeds
        let back = DirectIo.read_snapshot(&path, 0, 0).expect("clean re-read");
        assert_eq!(snapshot::encode(&back), snapshot::encode(&snap));
        std::fs::remove_dir_all(&dir).ok();
    }
}
