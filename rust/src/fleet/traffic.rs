//! Offline traffic shaping shared by every fleet driver — the CLI demo
//! (`tinycl fleet`), `examples/fleet_serving.rs`, `benches/fleet.rs` and
//! the integration tests. One implementation of the canonical event
//! stream so the surfaces can never drift apart (`BENCH_fleet.json`'s
//! methodology depends on them driving the SAME traffic shape).

use crate::coordinator::protocol::{build_schedule, Event};
use crate::runtime::manifest::ProtocolCfg;
use crate::runtime::Dataset;
use crate::util::rng::Rng;

use super::server::FleetEvent;
use super::tenant::TenantId;

/// The pre-deployment pool (initial classes x initial sessions) as
/// images + labels — what every tenant's replay memory seeds from.
/// Embed it once per server ([`FleetServer::embed_images`]) and admit
/// with [`FleetServer::admit_prepared`].
///
/// [`FleetServer::embed_images`]: super::FleetServer::embed_images
/// [`FleetServer::admit_prepared`]: super::FleetServer::admit_prepared
pub fn init_pool(ds: &Dataset) -> (Vec<f32>, Vec<i32>) {
    let init = ds.initial_indices();
    let img = ds.image_elems();
    let mut images = vec![0f32; init.len() * img];
    let mut labels = vec![0i32; init.len()];
    for (i, &idx) in init.iter().enumerate() {
        ds.train_image_into(idx, &mut images[i * img..(i + 1) * img]);
        labels[i] = ds.train_labels[idx];
    }
    (images, labels)
}

/// The schedule-RNG seed `run_protocol` derives from a session seed —
/// exposed so fleet drivers replay the very same NICv2 schedule a
/// single-session run of that seed would see (the N=1 parity tests
/// assert bit-equality on top of this).
pub fn schedule_seed(session_seed: u64) -> u64 {
    session_seed.wrapping_mul(0xA5A5_A5A5).wrapping_add(1)
}

/// Per-tenant NICv2 schedules interleaved round-robin: event `e` of
/// every tenant, in tenant order, before event `e + 1` of anyone —
/// the canonical many-learners-at-once traffic shape. `tenants` pairs
/// each id with its session seed (each tenant walks its own shuffled
/// schedule, exactly the one `run_protocol` would use for that seed).
pub fn interleaved_nicv2(
    protocol: &ProtocolCfg,
    ds: &Dataset,
    tenants: &[(TenantId, u64)],
    events_per_tenant: usize,
) -> Vec<FleetEvent> {
    nicv2_window(protocol, ds, tenants, 0, events_per_tenant)
}

/// The `[skip, skip + take)` window of every tenant's NICv2 schedule,
/// round-robin interleaved. `interleaved_nicv2` is the `skip = 0` case;
/// a non-zero `skip` continues tenants mid-schedule — the second leg of
/// a spill→restore→train trajectory replays exactly the events the
/// never-spilled run would see next (the bit-parity tests lean on this).
pub fn nicv2_window(
    protocol: &ProtocolCfg,
    ds: &Dataset,
    tenants: &[(TenantId, u64)],
    skip: usize,
    take: usize,
) -> Vec<FleetEvent> {
    let schedules: Vec<Vec<Event>> = tenants
        .iter()
        .map(|&(_, seed)| build_schedule(protocol, &mut Rng::new(schedule_seed(seed))))
        .collect();
    let mut events = Vec::new();
    for e in skip..skip + take {
        for (&(id, _), sched) in tenants.iter().zip(&schedules) {
            if let Some(ev) = sched.get(e) {
                events.push(FleetEvent::from_dataset(ds, id, ev.class, ev.session));
            }
        }
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn schedule_seed_matches_run_protocol_derivation() {
        // coordinator::run_protocol seeds its schedule rng with exactly
        // this expression — the N=1 parity guarantee starts here
        let seed = 100u64;
        assert_eq!(schedule_seed(seed), seed.wrapping_mul(0xA5A5_A5A5).wrapping_add(1));
        // distinct seeds -> distinct schedules (sanity on the fork)
        assert_ne!(
            Rng::new(schedule_seed(1)).next_u64(),
            Rng::new(schedule_seed(2)).next_u64()
        );
    }
}
