//! The redesigned client-facing fleet API: one builder, one error
//! type, one trait — shared by in-process and remote serving.
//!
//! Three pieces:
//!
//! - [`FleetConfigBuilder`] — the supported way to assemble a
//!   [`FleetConfig`]. The raw struct keeps its public fields for
//!   within-crate plumbing, but call sites (CLI, examples, benches) go
//!   through the builder so cross-field invariants (watermark ordering,
//!   non-zero queue depth) are checked once, here, instead of failing
//!   deep inside the governor;
//! - [`FleetError`] — the single error enum every client-visible
//!   failure maps onto. Each variant carries a stable wire code
//!   ([`FleetError::code`]) so the network protocol's reply codes map
//!   1:1 onto variants and a remote failure decodes back into exactly
//!   the error a local call would have returned;
//! - [`FleetApi`] — the serving verbs (admit / submit / infer /
//!   evaluate / drain / restore), implemented by [`LocalClient`] over an
//!   in-process [`FleetServer`] and by
//!   [`crate::net::client::RemoteClient`] over a TCP connection to a
//!   shard. [`crate::fleet::shard::FleetClient`] composes many remotes
//!   behind the same trait with tenant routing.
//!
//! [`submit_with_backoff`] is the canonical overload loop: it sleeps
//! *exactly* the `retry_after_ms` the server quoted (the server doubles
//! the quote per consecutive shed), so a well-behaved client converges
//! instead of hammering a saturated shard.

use std::collections::BTreeMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::runtime::Dataset;
use crate::telemetry::Telemetry;

use super::faults::{FaultPlan, RetryPolicy};
use super::server::{
    Admission, FleetConfig, FleetReport, FleetServer, InferRequest, Rejected, ServingSession,
    Submitted,
};
use super::snapshot;
use super::tenant::{TenantConfig, TenantId};
use super::traffic;

// ---------------------------------------------------------------------------
// FleetError
// ---------------------------------------------------------------------------

/// Every failure a fleet client can see, local or remote. Variants
/// carry a stable wire code so [`crate::net::frame`] encodes them
/// losslessly; the codes share the reply-code space (0..8 are success
/// shapes, 8.. are errors — overload is the one failure with its own
/// first-class reply code because clients act on its payload).
#[derive(Clone, Debug, PartialEq)]
pub enum FleetError {
    /// Admission control shed the submit; resubmit after exactly
    /// `retry_after_ms` (the server doubles the quote per consecutive
    /// shed and resets it on the next admit).
    Overloaded { retry_after_ms: u64 },
    /// The tenant id is not admitted on the shard that was asked.
    UnknownTenant { tenant: u64 },
    /// Admission failed for a reason backoff cannot fix (slot table
    /// full, duplicate admit, budget exhausted even after relief).
    Admission(String),
    /// The wire conversation itself is broken (bad magic, version skew,
    /// malformed frame, unexpected reply shape).
    Protocol(String),
    /// Transport or spill-tier I/O failure.
    Io(String),
    /// A server-side invariant failure surfaced to the client.
    Internal(String),
    /// A configuration rejected by [`FleetConfigBuilder::build`].
    Config(String),
    /// The shard this tenant routes to is marked down (failed
    /// heartbeats or exhausted transport retries); retry after the
    /// supervisor has had `retry_after_ms` to restart it.
    ShardDown { retry_after_ms: u64 },
}

impl FleetError {
    /// Wire code for [`Overloaded`](FleetError::Overloaded) — shared
    /// with the protocol's first-class `Rejected` reply, which carries
    /// the same single-`u64` payload.
    pub const CODE_OVERLOADED: u8 = 3;
    pub const CODE_UNKNOWN_TENANT: u8 = 8;
    pub const CODE_ADMISSION: u8 = 9;
    pub const CODE_PROTOCOL: u8 = 10;
    pub const CODE_IO: u8 = 11;
    pub const CODE_INTERNAL: u8 = 12;
    pub const CODE_CONFIG: u8 = 13;
    // 14 is the protocol's Duplicate success code
    pub const CODE_SHARD_DOWN: u8 = 15;

    /// The stable wire code this variant serializes under.
    pub fn code(&self) -> u8 {
        match self {
            FleetError::Overloaded { .. } => Self::CODE_OVERLOADED,
            FleetError::UnknownTenant { .. } => Self::CODE_UNKNOWN_TENANT,
            FleetError::Admission(_) => Self::CODE_ADMISSION,
            FleetError::Protocol(_) => Self::CODE_PROTOCOL,
            FleetError::Io(_) => Self::CODE_IO,
            FleetError::Internal(_) => Self::CODE_INTERNAL,
            FleetError::Config(_) => Self::CODE_CONFIG,
            FleetError::ShardDown { .. } => Self::CODE_SHARD_DOWN,
        }
    }

    /// True when retrying (after the quoted backoff) can succeed.
    pub fn is_retryable(&self) -> bool {
        matches!(self, FleetError::Overloaded { .. } | FleetError::ShardDown { .. })
    }

    /// Wrap a server-side `anyhow` failure, keeping the cause chain.
    pub fn internal(e: anyhow::Error) -> FleetError {
        FleetError::Internal(format!("{e:#}"))
    }
}

impl fmt::Display for FleetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FleetError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded: retry after {retry_after_ms} ms")
            }
            FleetError::UnknownTenant { tenant } => write!(f, "unknown tenant {tenant}"),
            FleetError::Admission(m) => write!(f, "admission refused: {m}"),
            FleetError::Protocol(m) => write!(f, "protocol error: {m}"),
            FleetError::Io(m) => write!(f, "i/o error: {m}"),
            FleetError::Internal(m) => write!(f, "internal error: {m}"),
            FleetError::Config(m) => write!(f, "invalid config: {m}"),
            FleetError::ShardDown { retry_after_ms } => {
                write!(f, "shard down: retry after {retry_after_ms} ms")
            }
        }
    }
}

impl std::error::Error for FleetError {}

impl From<Rejected> for FleetError {
    fn from(r: Rejected) -> FleetError {
        match r {
            Rejected::Overloaded { retry_after_ms, .. } => FleetError::Overloaded { retry_after_ms },
        }
    }
}

impl From<std::io::Error> for FleetError {
    fn from(e: std::io::Error) -> FleetError {
        FleetError::Io(e.to_string())
    }
}

// ---------------------------------------------------------------------------
// FleetConfigBuilder
// ---------------------------------------------------------------------------

/// Builder over [`FleetConfig`]: chainable setters, cross-field
/// validation at [`build`](FleetConfigBuilder::build).
#[derive(Clone, Debug)]
pub struct FleetConfigBuilder {
    cfg: FleetConfig,
}

impl FleetConfig {
    /// Start a builder at the defaults for split `l`.
    pub fn builder(l: usize) -> FleetConfigBuilder {
        FleetConfigBuilder { cfg: FleetConfig::new(l) }
    }
}

impl FleetConfigBuilder {
    /// Frozen stage precision: INT-8 (true, default) or FP32 baseline.
    pub fn int8_frozen(mut self, v: bool) -> Self {
        self.cfg.int8_frozen = v;
        self
    }

    /// Global governor byte budget.
    pub fn budget_bytes(mut self, v: usize) -> Self {
        self.cfg.governor.budget_bytes = v;
        self
    }

    /// Global governor budget in MiB (CLI convenience).
    pub fn budget_mb(self, v: usize) -> Self {
        self.budget_bytes(v << 20)
    }

    /// Boost trigger as a fraction of the budget.
    pub fn low_watermark(mut self, v: f64) -> Self {
        self.cfg.governor.low_watermark = v;
        self
    }

    /// Boost ceiling as a fraction of the budget.
    pub fn high_watermark(mut self, v: f64) -> Self {
        self.cfg.governor.high_watermark = v;
        self
    }

    /// Demotion floor: replay buffers never drop below this bit width.
    pub fn min_bits(mut self, v: u8) -> Self {
        self.cfg.governor.min_bits = v;
        self
    }

    /// Shrink floor: replay capacity never drops below this.
    pub fn min_slots(mut self, v: usize) -> Self {
        self.cfg.governor.min_slots = v;
        self
    }

    /// Slot-table size — the hard cap on concurrently resident tenants.
    pub fn max_tenants(mut self, v: usize) -> Self {
        self.cfg.max_tenants = v;
        self
    }

    /// Bounded ingress depth before submit blocks (or sheds).
    pub fn queue_depth(mut self, v: usize) -> Self {
        self.cfg.queue_depth = v;
        self
    }

    /// Max events one worker coalesces into a single frozen call.
    pub fn coalesce(mut self, v: usize) -> Self {
        self.cfg.coalesce = v;
        self
    }

    /// Enable the cold disk tier under this directory.
    pub fn spill_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cfg.spill_dir = Some(dir.into());
        self
    }

    /// Deterministic fault-injection schedule (chaos runs).
    pub fn faults(mut self, v: FaultPlan) -> Self {
        self.cfg.faults = v;
        self
    }

    /// Retry-with-backoff policy for cold-tier I/O.
    pub fn retry(mut self, v: RetryPolicy) -> Self {
        self.cfg.retry = v;
        self
    }

    /// Ingress admission control (block vs shed-with-quote).
    pub fn admission(mut self, v: Admission) -> Self {
        self.cfg.admission = v;
        self
    }

    /// Shorthand for [`Admission::Shed`] with this deadline.
    pub fn shed_after_ms(self, max_wait_ms: u64) -> Self {
        self.admission(Admission::Shed { max_wait_ms })
    }

    /// Execution-pool configuration (worker threads, lanes).
    pub fn exec(mut self, v: crate::exec::ExecConfig) -> Self {
        self.cfg.exec = v;
        self
    }

    /// Telemetry sink for spans, histograms and SLO counters.
    pub fn telemetry(mut self, v: Telemetry) -> Self {
        self.cfg.telemetry = v;
        self
    }

    /// Validate cross-field invariants and hand back the config.
    pub fn build(self) -> Result<FleetConfig, FleetError> {
        let c = &self.cfg;
        let g = &c.governor;
        let fail = |m: String| Err(FleetError::Config(m));
        if !(g.low_watermark > 0.0 && g.low_watermark < g.high_watermark && g.high_watermark <= 1.0)
        {
            return fail(format!(
                "watermarks must satisfy 0 < low < high <= 1 (got low={}, high={})",
                g.low_watermark, g.high_watermark
            ));
        }
        if g.budget_bytes == 0 {
            return fail("budget_bytes must be non-zero".into());
        }
        if !(1..=8).contains(&g.min_bits) {
            return fail(format!("min_bits must be in 1..=8 (got {})", g.min_bits));
        }
        if c.max_tenants == 0 {
            return fail("max_tenants must be at least 1".into());
        }
        if c.queue_depth == 0 {
            return fail("queue_depth must be at least 1".into());
        }
        if c.coalesce == 0 {
            return fail("coalesce must be at least 1".into());
        }
        if let Admission::Shed { max_wait_ms: 0 } = c.admission {
            return fail("shed deadline must be at least 1 ms".into());
        }
        Ok(self.cfg)
    }
}

// ---------------------------------------------------------------------------
// FleetApi
// ---------------------------------------------------------------------------

/// The serving verbs, identical across local and remote transports.
/// Tenant ids here are *global* (client-chosen `u64`); each
/// implementation maps them to shard-local slots internally.
pub trait FleetApi {
    /// Admit a new tenant under `cfg`, seeding its replay memory from
    /// the server's initial pool.
    fn admit(&mut self, tenant: u64, cfg: TenantConfig) -> Result<(), FleetError>;

    /// Submit one training event (raw images + labels). Returns
    /// [`FleetError::Overloaded`] with a backoff quote when shed.
    fn submit(&mut self, tenant: u64, images: &[f32], labels: &[i32]) -> Result<(), FleetError>;

    /// Run inference on `rows` images, returning row-major logits.
    fn infer(&mut self, tenant: u64, images: &[f32], rows: u32) -> Result<Vec<f32>, FleetError>;

    /// Quiesce the tenant's queued work, then score the full test split.
    fn evaluate(&mut self, tenant: u64) -> Result<f64, FleetError>;

    /// Quiesce, then evict the tenant and return its encoded snapshot —
    /// the outbound half of a live migration.
    fn drain(&mut self, tenant: u64) -> Result<Vec<u8>, FleetError>;

    /// Restore a drained tenant from its snapshot bytes — the inbound
    /// half of a live migration.
    fn restore(&mut self, tenant: u64, snapshot: &[u8]) -> Result<(), FleetError>;
}

/// What one [`submit_with_backoff`] call went through before landing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SubmitOutcome {
    /// sheds absorbed before the event was accepted
    pub sheds: u32,
    /// total milliseconds slept across the quoted backoffs
    pub waited_ms: u64,
}

/// Submit with server-quoted backoff: on [`FleetError::Overloaded`],
/// sleep *exactly* the quoted `retry_after_ms` and resubmit, up to
/// `max_attempts` total attempts. Any other error aborts immediately.
pub fn submit_with_backoff<C: FleetApi + ?Sized>(
    client: &mut C,
    tenant: u64,
    images: &[f32],
    labels: &[i32],
    max_attempts: u32,
) -> Result<SubmitOutcome, FleetError> {
    let mut out = SubmitOutcome::default();
    loop {
        match client.submit(tenant, images, labels) {
            Ok(()) => return Ok(out),
            Err(FleetError::Overloaded { retry_after_ms }) => {
                out.sheds += 1;
                if out.sheds >= max_attempts {
                    return Err(FleetError::Overloaded { retry_after_ms });
                }
                out.waited_ms += retry_after_ms;
                std::thread::sleep(Duration::from_millis(retry_after_ms));
            }
            Err(e) => return Err(e),
        }
    }
}

// ---------------------------------------------------------------------------
// LocalClient
// ---------------------------------------------------------------------------

/// In-process [`FleetApi`] over a [`FleetServer`] + [`ServingSession`]:
/// the same verbs a [`crate::net::client::RemoteClient`] speaks over
/// TCP, with no sockets in between. Single-shard deployments and tests
/// use this; the shard server wires the identical call sequence to its
/// connection handlers, which is what keeps local and remote serving
/// behaviourally equal.
pub struct LocalClient {
    server: Arc<FleetServer>,
    ds: Arc<Dataset>,
    init_images: Vec<f32>,
    init_labels: Vec<i32>,
    tenants: BTreeMap<u64, TenantId>,
    session: Option<ServingSession>,
    // Held so kernel/pool spans land in this server's sink while the
    // client serves; !Send, which pins LocalClient to its thread.
    _tm: Option<crate::telemetry::InstallGuard>,
}

impl LocalClient {
    /// Wrap a server; the initial replay pool is embedded once from the
    /// dataset's init split (shared by every admit).
    pub fn new(server: Arc<FleetServer>, ds: Arc<Dataset>) -> LocalClient {
        let (init_images, init_labels) = traffic::init_pool(&ds);
        LocalClient {
            server,
            ds,
            init_images,
            init_labels,
            tenants: BTreeMap::new(),
            session: None,
            _tm: None,
        }
    }

    /// The wrapped server (stats, governor introspection).
    pub fn server(&self) -> &Arc<FleetServer> {
        &self.server
    }

    /// The shard-local slot a global tenant id maps to, if admitted.
    pub fn local_id(&self, tenant: u64) -> Option<TenantId> {
        self.tenants.get(&tenant).copied()
    }

    /// Start serving: spin up `workers` pool workers draining the
    /// bounded queue. Must be called before `submit`.
    pub fn serve(&mut self, workers: usize) -> Result<(), FleetError> {
        if self.session.is_some() {
            return Err(FleetError::Internal("serve() called twice".into()));
        }
        self._tm = self.server.install_telemetry();
        self.session = Some(self.server.start_session(workers));
        Ok(())
    }

    /// Stop serving: drain the queue, join the workers, and hand back
    /// the run report (worker errors surface here).
    pub fn finish(&mut self) -> Result<FleetReport, FleetError> {
        let session = self
            .session
            .take()
            .ok_or_else(|| FleetError::Internal("finish() without serve()".into()))?;
        let report = session.finish().map_err(FleetError::internal)?;
        self._tm = None;
        Ok(report)
    }

    fn resolve(&self, tenant: u64) -> Result<TenantId, FleetError> {
        self.tenants
            .get(&tenant)
            .copied()
            .ok_or(FleetError::UnknownTenant { tenant })
    }

    fn wait_quiesced(&self, id: TenantId) -> Result<(), FleetError> {
        wait_quiesced(&self.server, id)
    }
}

/// Poll until the tenant's stamped work is fully applied (resident) or
/// its snapshot covers every stamp (spilled). Bounded so a wedged
/// worker surfaces as an error instead of a hang.
pub fn wait_quiesced(server: &FleetServer, id: TenantId) -> Result<(), FleetError> {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        if server.quiesced(id).map_err(FleetError::internal)? {
            return Ok(());
        }
        if Instant::now() >= deadline {
            return Err(FleetError::Internal(format!(
                "tenant {id} did not quiesce within 120 s"
            )));
        }
        std::thread::sleep(Duration::from_micros(200));
    }
}

impl FleetApi for LocalClient {
    fn admit(&mut self, tenant: u64, cfg: TenantConfig) -> Result<(), FleetError> {
        if self.tenants.contains_key(&tenant) {
            return Err(FleetError::Admission(format!("tenant {tenant} already admitted")));
        }
        let id = self
            .server
            .admit(cfg, &self.init_images, &self.init_labels)
            .map_err(|e| FleetError::Admission(format!("{e:#}")))?;
        self.tenants.insert(tenant, id);
        Ok(())
    }

    fn submit(&mut self, tenant: u64, images: &[f32], labels: &[i32]) -> Result<(), FleetError> {
        let id = self.resolve(tenant)?;
        let session = self
            .session
            .as_ref()
            .ok_or_else(|| FleetError::Internal("submit before serve()".into()))?;
        match session
            .submit_event(id, images.to_vec(), labels.to_vec())
            .map_err(FleetError::internal)?
        {
            Submitted::Enqueued => Ok(()),
            Submitted::Shed { retry_after_ms } => Err(FleetError::Overloaded { retry_after_ms }),
        }
    }

    fn infer(&mut self, tenant: u64, images: &[f32], _rows: u32) -> Result<Vec<f32>, FleetError> {
        let id = self.resolve(tenant)?;
        let mut out = self
            .server
            .infer_batch(&[InferRequest { tenant: id, images }])
            .map_err(FleetError::internal)?;
        Ok(out.pop().unwrap_or_default())
    }

    fn evaluate(&mut self, tenant: u64) -> Result<f64, FleetError> {
        let id = self.resolve(tenant)?;
        self.wait_quiesced(id)?;
        self.server
            .evaluate_tenant(&self.ds, id)
            .map_err(FleetError::internal)
    }

    fn drain(&mut self, tenant: u64) -> Result<Vec<u8>, FleetError> {
        let id = self.resolve(tenant)?;
        self.wait_quiesced(id)?;
        let snap = self.server.evict(id).map_err(FleetError::internal)?;
        self.tenants.remove(&tenant);
        Ok(snapshot::encode(&snap))
    }

    fn restore(&mut self, tenant: u64, bytes: &[u8]) -> Result<(), FleetError> {
        if self.tenants.contains_key(&tenant) {
            return Err(FleetError::Admission(format!("tenant {tenant} already resident")));
        }
        let snap = snapshot::decode(bytes).map_err(|e| FleetError::Protocol(format!("{e:#}")))?;
        let id = self.server.restore(snap).map_err(FleetError::internal)?;
        self.tenants.insert(tenant, id);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accepts_defaults_and_rejects_bad_invariants() {
        assert!(FleetConfig::builder(15).build().is_ok());
        let cfg = FleetConfig::builder(15)
            .budget_mb(4)
            .max_tenants(8)
            .queue_depth(64)
            .coalesce(4)
            .shed_after_ms(2)
            .build()
            .unwrap();
        assert_eq!(cfg.governor.budget_bytes, 4 << 20);
        assert_eq!(cfg.max_tenants, 8);
        assert_eq!(cfg.admission, Admission::Shed { max_wait_ms: 2 });

        let bad = |b: FleetConfigBuilder| match b.build() {
            Err(FleetError::Config(_)) => {}
            other => panic!("expected Config error, got {other:?}"),
        };
        bad(FleetConfig::builder(15).low_watermark(0.9).high_watermark(0.5));
        bad(FleetConfig::builder(15).high_watermark(1.5));
        bad(FleetConfig::builder(15).budget_bytes(0));
        bad(FleetConfig::builder(15).min_bits(0));
        bad(FleetConfig::builder(15).min_bits(9));
        bad(FleetConfig::builder(15).max_tenants(0));
        bad(FleetConfig::builder(15).queue_depth(0));
        bad(FleetConfig::builder(15).coalesce(0));
        bad(FleetConfig::builder(15).shed_after_ms(0));
    }

    #[test]
    fn error_codes_are_stable_and_disjoint() {
        let all = [
            FleetError::Overloaded { retry_after_ms: 1 },
            FleetError::UnknownTenant { tenant: 0 },
            FleetError::Admission(String::new()),
            FleetError::Protocol(String::new()),
            FleetError::Io(String::new()),
            FleetError::Internal(String::new()),
            FleetError::Config(String::new()),
            FleetError::ShardDown { retry_after_ms: 1 },
        ];
        let codes: Vec<u8> = all.iter().map(|e| e.code()).collect();
        assert_eq!(codes, vec![3, 8, 9, 10, 11, 12, 13, 15]);
        let mut sorted = codes.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), codes.len());
        assert!(FleetError::Overloaded { retry_after_ms: 4 }.is_retryable());
        assert!(FleetError::ShardDown { retry_after_ms: 4 }.is_retryable());
        assert!(!FleetError::Io("x".into()).is_retryable());
    }

    #[test]
    fn rejected_maps_onto_overloaded() {
        let r = Rejected::Overloaded { tenant: 3, retry_after_ms: 16 };
        assert_eq!(FleetError::from(r), FleetError::Overloaded { retry_after_ms: 16 });
    }
}
