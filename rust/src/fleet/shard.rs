//! Tenant routing across shard processes: the pure tenant→shard hash,
//! the migration-aware [`ShardRouter`], and [`FleetClient`] — a
//! multi-shard [`FleetApi`] with crash-safe live migration and
//! health-aware failover.
//!
//! Routing is a pure function: [`shard_of`] is the SplitMix64 finalizer
//! over the tenant id, reduced modulo the shard count. No coordination,
//! no lookup table — every client computes the same placement from
//! `(tenant, shard_count)` alone. Live migrations overlay that with
//! explicit pins ([`ShardRouter::pin`]), which travel with the client
//! that performed the migration.
//!
//! A live migration is now a crash-safe two-phase move, sequenced so
//! the tenant is never live on two shards and never lost — under ANY
//! single fault:
//!
//! 1. `Drain` on the source — quiesce, evict, ship the snapshot bytes
//!    back; the source KEEPS a durable tombstone (atomic-renamed
//!    `.tomb` file) until the move resolves;
//! 2. `Restore` on the target — decode, validate, adopt into a slot;
//! 3. resolve: `MigrateCommit` on the source drops the tombstone
//!    (success), or `MigrateAbort` resurrects the tenant from it
//!    (failed restore). Both verbs are idempotent, so they survive
//!    retries and re-delivery.
//!
//! If the resolution itself cannot be delivered (the source is down,
//! the client's connection died), the outcome is *remembered* in a
//! pending map and replayed by [`FleetClient::resolve_pending`] after
//! the shard comes back — a crashed client can even be replaced: the
//! source's tombstone plus the idempotent verbs make the resolution
//! safe to re-drive from scratch. A failed migration always restores
//! the router to the source (no pin-map entry ever points at a shard
//! that never received the tenant).
//!
//! Failover: [`FleetClient::heartbeat`] pings every shard; after
//! [`HEARTBEAT_MISSES`] consecutive misses a shard is marked down and
//! requests routed to it fail fast with
//! [`FleetError::ShardDown`]`{retry_after_ms}` instead of hanging.
//! When the supervisor restarts the shard,
//! [`FleetClient::re_resolve`] reconnects, clears the mark and counts
//! one failover.

use std::collections::BTreeMap;

use super::api::{FleetApi, FleetError};
use super::faults::{FaultPlan, RetryPolicy};
use super::tenant::TenantConfig;
use crate::net::chaos::{DirectNet, FaultyNet, NetIo};
use crate::net::client::RemoteClient;
use crate::net::frame::ShardStats;

/// The pure tenant→shard placement: SplitMix64 finalizer mod `shards`.
/// Deterministic across processes, hosts and sessions; uniform enough
/// that tenant ids assigned sequentially spread across shards.
pub fn shard_of(tenant: u64, shards: usize) -> usize {
    assert!(shards >= 1, "shard_of needs at least one shard");
    let mut z = tenant.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % shards as u64) as usize
}

/// Hash routing plus the migration pin overlay.
#[derive(Clone, Debug)]
pub struct ShardRouter {
    shards: usize,
    pins: BTreeMap<u64, usize>,
}

impl ShardRouter {
    pub fn new(shards: usize) -> ShardRouter {
        assert!(shards >= 1, "router needs at least one shard");
        ShardRouter { shards, pins: BTreeMap::new() }
    }

    pub fn shard_count(&self) -> usize {
        self.shards
    }

    /// The hash placement, ignoring pins.
    pub fn home(&self, tenant: u64) -> usize {
        shard_of(tenant, self.shards)
    }

    /// Where the tenant actually lives: its pin if migrated, else home.
    pub fn route(&self, tenant: u64) -> usize {
        self.pins.get(&tenant).copied().unwrap_or_else(|| self.home(tenant))
    }

    /// Record a migration. A pin back to the home shard is dropped —
    /// routing state stays minimal.
    pub fn pin(&mut self, tenant: u64, shard: usize) {
        assert!(shard < self.shards, "pin to shard {shard} of {}", self.shards);
        if shard == self.home(tenant) {
            self.pins.remove(&tenant);
        } else {
            self.pins.insert(tenant, shard);
        }
    }

    /// Drop any pin for `tenant` (route falls back to home).
    pub fn unpin(&mut self, tenant: u64) {
        self.pins.remove(&tenant);
    }

    /// Current migration pins (tenant → shard).
    pub fn pins(&self) -> &BTreeMap<u64, usize> {
        &self.pins
    }
}

/// One live migration the client performed (tenant, from, to).
pub type Migration = (u64, usize, usize);

/// An unresolved migration outcome, replayed by
/// [`FleetClient::resolve_pending`] once the source shard answers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pending {
    /// The move committed on the destination; the source still holds a
    /// tombstone that must be dropped.
    CommitDue { shard: usize },
    /// The move failed; the source must resurrect from its tombstone.
    AbortDue { shard: usize },
}

/// Pressure gap (hottest minus coldest shard, as fractions of their
/// budgets) below which [`FleetClient::rebalance`] leaves the placement
/// alone — the hysteresis that keeps tenants from ping-ponging.
pub const REBALANCE_GAP: f64 = 0.10;

/// Consecutive failed heartbeats before a shard is marked down.
pub const HEARTBEAT_MISSES: u32 = 3;

/// The quote surfaced with [`FleetError::ShardDown`]: how long callers
/// should wait before asking again (the supervisor's restart latency is
/// the real bound; this is a polite floor).
pub const SHARD_DOWN_RETRY_MS: u64 = 50;

#[derive(Default, Clone, Copy)]
struct Health {
    misses: u32,
    down: bool,
}

/// A client over the whole sharded fleet: routes every [`FleetApi`]
/// verb to the owning shard, performs crash-safe live migrations, and
/// rebalances on governor pressure.
pub struct FleetClient {
    shards: Vec<RemoteClient>,
    addrs: Vec<String>,
    retry: RetryPolicy,
    plan: FaultPlan,
    client_id: u64,
    router: ShardRouter,
    migrations: Vec<Migration>,
    /// tenant → unresolved migration outcome
    pending: BTreeMap<u64, Pending>,
    health: Vec<Health>,
    /// shards marked down and later recovered via [`Self::re_resolve`]
    failovers: u64,
}

impl FleetClient {
    /// Connect to every shard (order defines shard indices — every
    /// client of one fleet must list the same addresses in the same
    /// order) and handshake. Unstamped, fault-free — the drop-in
    /// production constructor.
    pub fn connect(addrs: &[String], retry: &RetryPolicy) -> Result<FleetClient, FleetError> {
        FleetClient::connect_with(addrs, retry, &FaultPlan::none(), 0)
    }

    /// Connect with a network fault plan and a stamping identity. A
    /// nonzero `client_id` makes every mutation idempotent (stamped,
    /// deduped server-side) and therefore safe to retry through the
    /// plan's injected drops, tears and stalls.
    pub fn connect_with(
        addrs: &[String],
        retry: &RetryPolicy,
        plan: &FaultPlan,
        client_id: u64,
    ) -> Result<FleetClient, FleetError> {
        if addrs.is_empty() {
            return Err(FleetError::Config("fleet client needs at least one shard".into()));
        }
        let mut shards = Vec::with_capacity(addrs.len());
        for addr in addrs {
            shards.push(RemoteClient::connect_with(addr, retry, net_io(plan), client_id)?);
        }
        let router = ShardRouter::new(addrs.len());
        Ok(FleetClient {
            shards,
            addrs: addrs.to_vec(),
            retry: retry.clone(),
            plan: plan.clone(),
            client_id,
            router,
            migrations: Vec::new(),
            pending: BTreeMap::new(),
            health: vec![Health::default(); addrs.len()],
            failovers: 0,
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn router(&self) -> &ShardRouter {
        &self.router
    }

    /// Every live migration performed through this client, in order.
    pub fn migrations(&self) -> &[Migration] {
        &self.migrations
    }

    /// Unresolved migration outcomes awaiting a reachable source shard.
    pub fn pending(&self) -> &BTreeMap<u64, Pending> {
        &self.pending
    }

    /// Shards marked down and later recovered.
    pub fn failovers(&self) -> u64 {
        self.failovers
    }

    /// Transport retries summed over every shard connection.
    pub fn net_retries(&self) -> u64 {
        self.shards.iter().map(|s| s.net_retries()).sum()
    }

    /// Duplicate acknowledgements summed over every shard connection.
    pub fn duplicates(&self) -> u64 {
        self.shards.iter().map(|s| s.duplicates()).sum()
    }

    /// Load reports from every shard, indexed by shard.
    pub fn stats(&mut self) -> Result<Vec<ShardStats>, FleetError> {
        self.shards.iter_mut().map(|s| s.stats()).collect()
    }

    fn check_up(&self, shard: usize) -> Result<(), FleetError> {
        if self.health[shard].down {
            return Err(FleetError::ShardDown { retry_after_ms: SHARD_DOWN_RETRY_MS });
        }
        Ok(())
    }

    /// Ping one shard and update its health. Returns whether it
    /// answered; [`HEARTBEAT_MISSES`] consecutive misses mark it down.
    pub fn ping_shard(&mut self, shard: usize) -> bool {
        match self.shards[shard].ping() {
            Ok(()) => {
                self.health[shard].misses = 0;
                true
            }
            Err(_) => {
                let h = &mut self.health[shard];
                h.misses += 1;
                if h.misses >= HEARTBEAT_MISSES {
                    h.down = true;
                }
                false
            }
        }
    }

    /// One heartbeat round: ping every shard; `true` per shard = alive.
    pub fn heartbeat(&mut self) -> Vec<bool> {
        (0..self.shards.len()).map(|i| self.ping_shard(i)).collect()
    }

    /// Is this shard currently marked down?
    pub fn is_down(&self, shard: usize) -> bool {
        self.health[shard].down
    }

    /// Mark a shard down explicitly (a caller observed it die — e.g.
    /// the supervisor reported a restart in progress).
    pub fn mark_down(&mut self, shard: usize) {
        self.health[shard].misses = HEARTBEAT_MISSES;
        self.health[shard].down = true;
    }

    /// Re-resolve routes after a supervisor restart: adopt the new
    /// address list (same length, same order — indices are identity),
    /// reconnect every shard marked down, clear its mark, and replay
    /// unresolved migration outcomes. Returns how many shards came
    /// back; each one counts as a failover.
    pub fn re_resolve(&mut self, addrs: &[String]) -> Result<usize, FleetError> {
        if addrs.len() != self.shards.len() {
            return Err(FleetError::Config(format!(
                "re-resolve with {} addresses for {} shards",
                addrs.len(),
                self.shards.len()
            )));
        }
        self.addrs = addrs.to_vec();
        let mut recovered = 0;
        for i in 0..self.shards.len() {
            if !self.health[i].down {
                continue;
            }
            let fresh = RemoteClient::connect_with(
                &self.addrs[i],
                &self.retry,
                net_io(&self.plan),
                self.client_id,
            )?;
            self.shards[i] = fresh;
            self.health[i] = Health::default();
            self.failovers += 1;
            recovered += 1;
        }
        self.resolve_pending();
        Ok(recovered)
    }

    /// Replay unresolved migration outcomes (commit or abort on the
    /// source). Outcomes whose shard still doesn't answer stay pending.
    /// Returns how many resolved.
    pub fn resolve_pending(&mut self) -> usize {
        let pending = std::mem::take(&mut self.pending);
        let mut resolved = 0;
        for (tenant, p) in pending {
            let ok = match p {
                Pending::CommitDue { shard } => self.shards[shard].migrate_commit(tenant).is_ok(),
                Pending::AbortDue { shard } => {
                    let ok = self.shards[shard].migrate_abort(tenant).is_ok();
                    if ok {
                        // the tenant lives on the source again
                        self.router.pin(tenant, shard);
                    }
                    ok
                }
            };
            if ok {
                resolved += 1;
            } else {
                self.pending.insert(tenant, p);
            }
        }
        resolved
    }

    /// Live-migrate `tenant` to shard `to`: drain (tombstone stays on
    /// the source) → restore on the target → commit (or abort). No
    /// single fault anywhere in the sequence loses the tenant, and no
    /// failure leaves a pin pointing at a shard that never received it.
    pub fn migrate(&mut self, tenant: u64, to: usize) -> Result<(), FleetError> {
        let from = self.router.route(tenant);
        if to >= self.shards.len() {
            return Err(FleetError::Config(format!(
                "migrate to shard {to} of {}",
                self.shards.len()
            )));
        }
        if to == from {
            return Ok(());
        }
        self.check_up(from)?;
        self.check_up(to)?;
        // phase 1: the source quiesces, evicts and tombstones
        let bytes = self.shards[from].drain(tenant)?;
        // phase 2: the destination adopts
        match self.shards[to].restore(tenant, &bytes) {
            Ok(()) => {
                self.router.pin(tenant, to);
                self.migrations.push((tenant, from, to));
                // resolution: drop the source's tombstone. If the
                // source is unreachable the move still stands — the
                // commit is remembered and replayed on re_resolve.
                if self.shards[from].migrate_commit(tenant).is_err() {
                    self.pending.insert(tenant, Pending::CommitDue { shard: from });
                }
                Ok(())
            }
            Err(e) => {
                // the move failed: the router must keep saying `from`
                // (and must NOT keep any stale pin for a partial move)
                self.router.pin(tenant, from);
                // resolution: resurrect from the source's tombstone. If
                // even the abort can't be delivered, remember it — the
                // tombstone keeps the tenant durable meanwhile.
                if self.shards[from].migrate_abort(tenant).is_err() {
                    self.pending.insert(tenant, Pending::AbortDue { shard: from });
                }
                Err(e)
            }
        }
    }

    /// One governor-pressure rebalance step: if the hottest shard's
    /// pressure exceeds the coldest's by more than [`REBALANCE_GAP`],
    /// move the hottest shard's *coldest* tenant (least-recently-active
    /// — the one whose working set is cheapest to interrupt) to the
    /// coldest shard. Returns the migration performed, if any.
    pub fn rebalance(&mut self) -> Result<Option<Migration>, FleetError> {
        let stats = self.stats()?;
        if stats.len() < 2 {
            return Ok(None);
        }
        let hottest = stats
            .iter()
            .max_by(|a, b| a.pressure().total_cmp(&b.pressure()))
            .expect("at least two shards");
        let coldest = stats
            .iter()
            .min_by(|a, b| a.pressure().total_cmp(&b.pressure()))
            .expect("at least two shards");
        if hottest.shard == coldest.shard
            || hottest.pressure() - coldest.pressure() <= REBALANCE_GAP
            || hottest.tenants.len() < 2
        {
            return Ok(None);
        }
        let victim = hottest
            .tenants
            .iter()
            .min_by_key(|t| t.last_active)
            .expect("hottest shard has tenants")
            .tenant;
        let to = coldest.shard as usize;
        let from = self.router.route(victim);
        self.migrate(victim, to)?;
        Ok(Some((victim, from, to)))
    }

    /// Ask every shard process to finish serving and exit.
    pub fn shutdown_all(&mut self) -> Result<(), FleetError> {
        for shard in &mut self.shards {
            shard.shutdown()?;
        }
        Ok(())
    }

    fn shard_for(&mut self, tenant: u64) -> Result<&mut RemoteClient, FleetError> {
        let i = self.router.route(tenant);
        self.check_up(i)?;
        Ok(&mut self.shards[i])
    }
}

/// Pick the io path for a plan: the direct one (no plan checks at all)
/// unless faults are actually scheduled.
fn net_io(plan: &FaultPlan) -> Box<dyn NetIo> {
    if plan.is_enabled() {
        Box::new(FaultyNet::new(plan.clone()))
    } else {
        Box::new(DirectNet)
    }
}

impl FleetApi for FleetClient {
    fn admit(&mut self, tenant: u64, cfg: TenantConfig) -> Result<(), FleetError> {
        self.shard_for(tenant)?.admit(tenant, cfg)
    }

    fn submit(&mut self, tenant: u64, images: &[f32], labels: &[i32]) -> Result<(), FleetError> {
        self.shard_for(tenant)?.submit(tenant, images, labels)
    }

    fn infer(&mut self, tenant: u64, images: &[f32], rows: u32) -> Result<Vec<f32>, FleetError> {
        self.shard_for(tenant)?.infer(tenant, images, rows)
    }

    fn evaluate(&mut self, tenant: u64) -> Result<f64, FleetError> {
        self.shard_for(tenant)?.evaluate(tenant)
    }

    fn drain(&mut self, tenant: u64) -> Result<Vec<u8>, FleetError> {
        self.shard_for(tenant)?.drain(tenant)
    }

    fn restore(&mut self, tenant: u64, snapshot: &[u8]) -> Result<(), FleetError> {
        self.shard_for(tenant)?.restore(tenant, snapshot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_matches_pinned_splitmix_reference_values() {
        // reference values computed independently from the SplitMix64
        // finalizer definition — these pin the placement function; a
        // change here strands every pinned tenant in a mixed fleet
        let two: Vec<usize> = (0..8).map(|t| shard_of(t, 2)).collect();
        assert_eq!(two, vec![1, 1, 0, 1, 0, 0, 0, 1]);
        let three: Vec<usize> = (0..8).map(|t| shard_of(t, 3)).collect();
        assert_eq!(three, vec![1, 2, 1, 0, 1, 2, 2, 0]);
        assert_eq!(shard_of(42, 4), 1);
        assert_eq!(shard_of(1000, 4), 0);
        assert_eq!(shard_of(1001, 4), 0);
    }

    #[test]
    fn shard_of_is_total_over_shard_counts() {
        for shards in 1..=8 {
            let mut hit = vec![false; shards];
            for t in 0..256u64 {
                let s = shard_of(t, shards);
                assert!(s < shards);
                hit[s] = true;
            }
            assert!(hit.iter().all(|&h| h), "{shards} shards: some shard never hit");
        }
    }

    #[test]
    fn router_pins_override_home_and_unpin_on_return() {
        let mut r = ShardRouter::new(2);
        let t = 2; // home is shard 0 under the pinned reference values
        assert_eq!(r.home(t), 0);
        assert_eq!(r.route(t), 0);
        r.pin(t, 1);
        assert_eq!(r.route(t), 1);
        assert_eq!(r.home(t), 0, "home is pure, pins don't move it");
        assert_eq!(r.pins().len(), 1);
        r.pin(t, 0); // migrating home drops the pin
        assert_eq!(r.route(t), 0);
        assert!(r.pins().is_empty());
    }

    #[test]
    fn unpin_falls_back_to_home() {
        let mut r = ShardRouter::new(2);
        r.pin(2, 1);
        assert_eq!(r.route(2), 1);
        r.unpin(2);
        assert_eq!(r.route(2), 0);
        r.unpin(2); // idempotent
        assert_eq!(r.route(2), 0);
    }

    #[test]
    #[should_panic(expected = "at least one shard")]
    fn zero_shards_is_a_programming_error() {
        shard_of(7, 0);
    }
}
