//! Tenant routing across shard processes: the pure tenant→shard hash,
//! the migration-aware [`ShardRouter`], and [`FleetClient`] — a
//! multi-shard [`FleetApi`] with live snapshot migration and
//! pressure-driven rebalancing.
//!
//! Routing is a pure function: [`shard_of`] is the SplitMix64 finalizer
//! over the tenant id, reduced modulo the shard count. No coordination,
//! no lookup table — every client computes the same placement from
//! `(tenant, shard_count)` alone. Live migrations overlay that with
//! explicit pins ([`ShardRouter::pin`]), which travel with the client
//! that performed the migration.
//!
//! A live migration is three protocol steps, sequenced so the tenant is
//! never live on two shards and never lost:
//!
//! 1. `Drain` on the source — quiesce (every stamped event applied),
//!    evict, ship the versioned snapshot bytes back;
//! 2. `Restore` on the target — decode, validate, adopt into a slot;
//! 3. pin the tenant to the target in the router.
//!
//! If the restore fails the client re-restores onto the source (the
//! bytes are still in hand), so the failure mode is "migration didn't
//! happen", not "tenant vanished". The snapshot format already
//! round-trips bit-exactly through the cold tier, which is what makes
//! step 2 produce a tenant whose future training is bit-identical to
//! one that never moved (`rust/tests/shard.rs`).

use std::collections::BTreeMap;

use super::api::{FleetApi, FleetError};
use super::faults::RetryPolicy;
use super::tenant::TenantConfig;
use crate::net::client::RemoteClient;
use crate::net::frame::ShardStats;

/// The pure tenant→shard placement: SplitMix64 finalizer mod `shards`.
/// Deterministic across processes, hosts and sessions; uniform enough
/// that tenant ids assigned sequentially spread across shards.
pub fn shard_of(tenant: u64, shards: usize) -> usize {
    assert!(shards >= 1, "shard_of needs at least one shard");
    let mut z = tenant.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % shards as u64) as usize
}

/// Hash routing plus the migration pin overlay.
#[derive(Clone, Debug)]
pub struct ShardRouter {
    shards: usize,
    pins: BTreeMap<u64, usize>,
}

impl ShardRouter {
    pub fn new(shards: usize) -> ShardRouter {
        assert!(shards >= 1, "router needs at least one shard");
        ShardRouter { shards, pins: BTreeMap::new() }
    }

    pub fn shard_count(&self) -> usize {
        self.shards
    }

    /// The hash placement, ignoring pins.
    pub fn home(&self, tenant: u64) -> usize {
        shard_of(tenant, self.shards)
    }

    /// Where the tenant actually lives: its pin if migrated, else home.
    pub fn route(&self, tenant: u64) -> usize {
        self.pins.get(&tenant).copied().unwrap_or_else(|| self.home(tenant))
    }

    /// Record a migration. A pin back to the home shard is dropped —
    /// routing state stays minimal.
    pub fn pin(&mut self, tenant: u64, shard: usize) {
        assert!(shard < self.shards, "pin to shard {shard} of {}", self.shards);
        if shard == self.home(tenant) {
            self.pins.remove(&tenant);
        } else {
            self.pins.insert(tenant, shard);
        }
    }

    /// Current migration pins (tenant → shard).
    pub fn pins(&self) -> &BTreeMap<u64, usize> {
        &self.pins
    }
}

/// One live migration the client performed (tenant, from, to).
pub type Migration = (u64, usize, usize);

/// Pressure gap (hottest minus coldest shard, as fractions of their
/// budgets) below which [`FleetClient::rebalance`] leaves the placement
/// alone — the hysteresis that keeps tenants from ping-ponging.
pub const REBALANCE_GAP: f64 = 0.10;

/// A client over the whole sharded fleet: routes every [`FleetApi`]
/// verb to the owning shard, performs live migrations, and rebalances
/// on governor pressure.
pub struct FleetClient {
    shards: Vec<RemoteClient>,
    router: ShardRouter,
    migrations: Vec<Migration>,
}

impl FleetClient {
    /// Connect to every shard (order defines shard indices — every
    /// client of one fleet must list the same addresses in the same
    /// order) and handshake.
    pub fn connect(addrs: &[String], retry: &RetryPolicy) -> Result<FleetClient, FleetError> {
        if addrs.is_empty() {
            return Err(FleetError::Config("fleet client needs at least one shard".into()));
        }
        let mut shards = Vec::with_capacity(addrs.len());
        for addr in addrs {
            shards.push(RemoteClient::connect(addr, retry)?);
        }
        let router = ShardRouter::new(addrs.len());
        Ok(FleetClient { shards, router, migrations: Vec::new() })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn router(&self) -> &ShardRouter {
        &self.router
    }

    /// Every live migration performed through this client, in order.
    pub fn migrations(&self) -> &[Migration] {
        &self.migrations
    }

    /// Load reports from every shard, indexed by shard.
    pub fn stats(&mut self) -> Result<Vec<ShardStats>, FleetError> {
        self.shards.iter_mut().map(|s| s.stats()).collect()
    }

    /// Live-migrate `tenant` to shard `to`: drain → transfer → restore
    /// → pin. On a failed restore the snapshot goes back to the source,
    /// so no outcome of this call loses the tenant.
    pub fn migrate(&mut self, tenant: u64, to: usize) -> Result<(), FleetError> {
        let from = self.router.route(tenant);
        if to >= self.shards.len() {
            return Err(FleetError::Config(format!(
                "migrate to shard {to} of {}",
                self.shards.len()
            )));
        }
        if to == from {
            return Ok(());
        }
        let bytes = self.shards[from].drain(tenant)?;
        match self.shards[to].restore(tenant, &bytes) {
            Ok(()) => {
                self.router.pin(tenant, to);
                self.migrations.push((tenant, from, to));
                Ok(())
            }
            Err(e) => {
                // put the tenant back where it came from; only if THAT
                // also fails is the tenant actually gone
                self.shards[from].restore(tenant, &bytes).map_err(|e2| {
                    FleetError::Internal(format!(
                        "tenant {tenant} lost in migration {from}->{to}: restore failed ({e}), \
                         rollback failed ({e2})"
                    ))
                })?;
                Err(e)
            }
        }
    }

    /// One governor-pressure rebalance step: if the hottest shard's
    /// pressure exceeds the coldest's by more than [`REBALANCE_GAP`],
    /// move the hottest shard's *coldest* tenant (least-recently-active
    /// — the one whose working set is cheapest to interrupt) to the
    /// coldest shard. Returns the migration performed, if any.
    pub fn rebalance(&mut self) -> Result<Option<Migration>, FleetError> {
        let stats = self.stats()?;
        if stats.len() < 2 {
            return Ok(None);
        }
        let hottest = stats
            .iter()
            .max_by(|a, b| a.pressure().total_cmp(&b.pressure()))
            .expect("at least two shards");
        let coldest = stats
            .iter()
            .min_by(|a, b| a.pressure().total_cmp(&b.pressure()))
            .expect("at least two shards");
        if hottest.shard == coldest.shard
            || hottest.pressure() - coldest.pressure() <= REBALANCE_GAP
            || hottest.tenants.len() < 2
        {
            return Ok(None);
        }
        let victim = hottest
            .tenants
            .iter()
            .min_by_key(|t| t.last_active)
            .expect("hottest shard has tenants")
            .tenant;
        let to = coldest.shard as usize;
        let from = self.router.route(victim);
        self.migrate(victim, to)?;
        Ok(Some((victim, from, to)))
    }

    /// Ask every shard process to finish serving and exit.
    pub fn shutdown_all(&mut self) -> Result<(), FleetError> {
        for shard in &mut self.shards {
            shard.shutdown()?;
        }
        Ok(())
    }

    fn shard_for(&mut self, tenant: u64) -> &mut RemoteClient {
        let i = self.router.route(tenant);
        &mut self.shards[i]
    }
}

impl FleetApi for FleetClient {
    fn admit(&mut self, tenant: u64, cfg: TenantConfig) -> Result<(), FleetError> {
        self.shard_for(tenant).admit(tenant, cfg)
    }

    fn submit(&mut self, tenant: u64, images: &[f32], labels: &[i32]) -> Result<(), FleetError> {
        self.shard_for(tenant).submit(tenant, images, labels)
    }

    fn infer(&mut self, tenant: u64, images: &[f32], rows: u32) -> Result<Vec<f32>, FleetError> {
        self.shard_for(tenant).infer(tenant, images, rows)
    }

    fn evaluate(&mut self, tenant: u64) -> Result<f64, FleetError> {
        self.shard_for(tenant).evaluate(tenant)
    }

    fn drain(&mut self, tenant: u64) -> Result<Vec<u8>, FleetError> {
        self.shard_for(tenant).drain(tenant)
    }

    fn restore(&mut self, tenant: u64, snapshot: &[u8]) -> Result<(), FleetError> {
        self.shard_for(tenant).restore(tenant, snapshot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_matches_pinned_splitmix_reference_values() {
        // reference values computed independently from the SplitMix64
        // finalizer definition — these pin the placement function; a
        // change here strands every pinned tenant in a mixed fleet
        let two: Vec<usize> = (0..8).map(|t| shard_of(t, 2)).collect();
        assert_eq!(two, vec![1, 1, 0, 1, 0, 0, 0, 1]);
        let three: Vec<usize> = (0..8).map(|t| shard_of(t, 3)).collect();
        assert_eq!(three, vec![1, 2, 1, 0, 1, 2, 2, 0]);
        assert_eq!(shard_of(42, 4), 1);
        assert_eq!(shard_of(1000, 4), 0);
        assert_eq!(shard_of(1001, 4), 0);
    }

    #[test]
    fn shard_of_is_total_over_shard_counts() {
        for shards in 1..=8 {
            let mut hit = vec![false; shards];
            for t in 0..256u64 {
                let s = shard_of(t, shards);
                assert!(s < shards);
                hit[s] = true;
            }
            assert!(hit.iter().all(|&h| h), "{shards} shards: some shard never hit");
        }
    }

    #[test]
    fn router_pins_override_home_and_unpin_on_return() {
        let mut r = ShardRouter::new(2);
        let t = 2; // home is shard 0 under the pinned reference values
        assert_eq!(r.home(t), 0);
        assert_eq!(r.route(t), 0);
        r.pin(t, 1);
        assert_eq!(r.route(t), 1);
        assert_eq!(r.home(t), 0, "home is pure, pins don't move it");
        assert_eq!(r.pins().len(), 1);
        r.pin(t, 0); // migrating home drops the pin
        assert_eq!(r.route(t), 0);
        assert!(r.pins().is_empty());
    }

    #[test]
    #[should_panic(expected = "at least one shard")]
    fn zero_shards_is_a_programming_error() {
        shard_of(7, 0);
    }
}
