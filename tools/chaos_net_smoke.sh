#!/usr/bin/env bash
# Partition-tolerance smoke drill (the CI `chaos-net-smoke` job's engine).
#
# Three legs over real `tinycl` processes on loopback:
#
#   1. CHAOS: a supervised 2-shard fleet where shard 1 is launched with
#      --crash-after-frames (it exits(9) mid-service, worst case mid-
#      migration with the restore applied but unacknowledged), driven by
#      a `tinycl shard-client` riding the seeded net_recovering fault
#      plan on a stamped client. The supervisor must restart the dead
#      shard (grep its restart + MTTR line), the client must fail over,
#      and zero tenants may be lost.
#   2. CONTROL: the identical workload, fault-free, unsupervised.
#   3. AUDIT: bench_check floors (tenants_lost == 0, net_retries >= 1,
#      failovers >= 1) on the chaos artifact, then a byte-diff of the
#      two runs' determinism blocks — injected chaos and a shard crash
#      must be bit-invisible in every tenant's accuracy.
#
# Usage: tools/chaos_net_smoke.sh [out_dir]
# Env:   TINYCL_BIN  path to the tinycl binary
#                    (default: target/release/tinycl, built if absent)
set -euo pipefail
cd "$(dirname "$0")/.."

OUT_DIR="${1:-/tmp/tinycl-chaos-net-smoke}"
mkdir -p "$OUT_DIR"

BIN="${TINYCL_BIN:-target/release/tinycl}"
if [ ! -x "$BIN" ]; then
  cargo build --release
fi

TENANTS=4
EVENTS=4
N_LR=128
SEED=1000
FAULT_SEED=11

PIDS=()
cleanup() {
  for pid in "${PIDS[@]}"; do
    kill "$pid" 2>/dev/null || true
  done
}
trap cleanup EXIT

wait_addr() { # logfile
  local log="$1" addr=""
  for _ in $(seq 1 200); do
    addr=$(sed -n 's/^shard [0-9]* listening on //p' "$log" | head -n 1)
    if [ -n "$addr" ]; then
      echo "$addr"
      return 0
    fi
    sleep 0.05
  done
  echo "shard never printed its address (log: $log)" >&2
  cat "$log" >&2
  return 1
}

wait_file() { # path
  for _ in $(seq 1 600); do
    if [ -s "$1" ]; then return 0; fi
    sleep 0.05
  done
  echo "file $1 never appeared" >&2
  return 1
}

echo "== chaos leg: supervised fleet, shard 1 booby-trapped, seeded net faults =="
ADDRS_FILE="$OUT_DIR/shard_addrs.txt"
rm -f "$ADDRS_FILE"
"$BIN" supervise \
  --shards 2 --workers 2 \
  --addrs-file "$ADDRS_FILE" \
  --spill-root "$OUT_DIR/spill" \
  --crash-shard 1 --crash-after-frames 1 \
  >"$OUT_DIR/supervisor.log" 2>&1 &
PIDS+=($!)
wait_file "$ADDRS_FILE"
echo "supervised shards at $(paste -sd, "$ADDRS_FILE")"

"$BIN" shard-client \
  --addrs-file "$ADDRS_FILE" \
  --tenants "$TENANTS" --events "$EVENTS" --n-lr "$N_LR" --seed "$SEED" \
  --client-id 42 --net-fault-plan "$FAULT_SEED" \
  --min-migrations 1 \
  --out "$OUT_DIR/BENCH_shard_chaos.json" \
  --shutdown | tee "$OUT_DIR/client_chaos.log"
wait "${PIDS[0]}"
PIDS=()

echo "== supervisor must have restarted the crashed shard =="
grep "restarted shard" "$OUT_DIR/supervisor.log" || {
  echo "supervisor never restarted a shard" >&2
  cat "$OUT_DIR/supervisor.log" >&2
  exit 1
}
grep -E "supervisor: [1-9][0-9]* restart" "$OUT_DIR/supervisor.log" || {
  echo "supervisor report shows no restarts (MTTR unmeasured)" >&2
  cat "$OUT_DIR/supervisor.log" >&2
  exit 1
}

echo "== control leg: same workload, no faults, no supervisor =="
"$BIN" shard --shard-index 0 --workers 2 >"$OUT_DIR/shard0.log" 2>&1 &
PIDS+=($!)
"$BIN" shard --shard-index 1 --workers 2 >"$OUT_DIR/shard1.log" 2>&1 &
PIDS+=($!)
ADDR0=$(wait_addr "$OUT_DIR/shard0.log")
ADDR1=$(wait_addr "$OUT_DIR/shard1.log")
echo "control shards at $ADDR0 , $ADDR1"

"$BIN" shard-client \
  --shards "$ADDR0,$ADDR1" \
  --tenants "$TENANTS" --events "$EVENTS" --n-lr "$N_LR" --seed "$SEED" \
  --min-migrations 1 \
  --out "$OUT_DIR/BENCH_shard_clean.json" \
  --shutdown
wait "${PIDS[0]}" "${PIDS[1]}"
PIDS=()

echo "== floors + chaos-vs-clean determinism diff =="
python3 tools/bench_check.py validate-shard "$OUT_DIR/BENCH_shard_chaos.json" \
  --min-migrations 1 --min-shards 2 \
  --min-net-retries 1 --min-failovers 1
python3 tools/bench_check.py validate-shard "$OUT_DIR/BENCH_shard_clean.json" \
  --min-migrations 1 --min-shards 2
python3 tools/bench_check.py diff \
  "$OUT_DIR/BENCH_shard_chaos.json" "$OUT_DIR/BENCH_shard_clean.json"
echo "chaos_net_smoke: OK (artifacts in $OUT_DIR)"
