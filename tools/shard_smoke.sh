#!/usr/bin/env bash
# Two-shard loopback smoke drill (the CI `shard-smoke` job's engine).
#
# Launches two `tinycl shard` processes on ephemeral loopback ports,
# drives them with `tinycl shard-client` (admit -> leg 1 -> at least one
# LIVE migration -> leg 2 -> evaluate), then repeats the identical
# workload against a single shard and byte-diffs the two runs'
# determinism blocks: per-tenant accuracy BITS must be identical
# whether the fleet had one shard or two, migration included. Floors
# (>= 1 migration, 0 tenants lost, acc-bit schema) are enforced by
# tools/bench_check.py validate-shard.
#
# Usage: tools/shard_smoke.sh [out_dir]
# Env:   TINYCL_BIN  path to the tinycl binary
#                    (default: target/release/tinycl, built if absent)
set -euo pipefail
cd "$(dirname "$0")/.."

OUT_DIR="${1:-/tmp/tinycl-shard-smoke}"
mkdir -p "$OUT_DIR"

BIN="${TINYCL_BIN:-target/release/tinycl}"
if [ ! -x "$BIN" ]; then
  cargo build --release
fi

TENANTS=4
EVENTS=4
N_LR=128
SEED=1000

PIDS=()
cleanup() {
  for pid in "${PIDS[@]}"; do
    kill "$pid" 2>/dev/null || true
  done
}
trap cleanup EXIT

# Wait for a shard log to print its machine-readable bound address.
wait_addr() { # logfile
  local log="$1" addr=""
  for _ in $(seq 1 200); do
    addr=$(sed -n 's/^shard [0-9]* listening on //p' "$log" | head -n 1)
    if [ -n "$addr" ]; then
      echo "$addr"
      return 0
    fi
    sleep 0.05
  done
  echo "shard never printed its address (log: $log)" >&2
  cat "$log" >&2
  return 1
}

echo "== two-shard leg =="
"$BIN" shard --shard-index 0 --workers 2 >"$OUT_DIR/shard0.log" 2>&1 &
PIDS+=($!)
"$BIN" shard --shard-index 1 --workers 2 >"$OUT_DIR/shard1.log" 2>&1 &
PIDS+=($!)
ADDR0=$(wait_addr "$OUT_DIR/shard0.log")
ADDR1=$(wait_addr "$OUT_DIR/shard1.log")
echo "shards at $ADDR0 , $ADDR1"

"$BIN" shard-client \
  --shards "$ADDR0,$ADDR1" \
  --tenants "$TENANTS" --events "$EVENTS" --n-lr "$N_LR" --seed "$SEED" \
  --min-migrations 1 \
  --out "$OUT_DIR/BENCH_shard_2.json" \
  --shutdown
wait "${PIDS[0]}" "${PIDS[1]}"
PIDS=()

echo "== one-shard control (same seeds, same traffic) =="
"$BIN" shard --shard-index 0 --workers 2 >"$OUT_DIR/shard_solo.log" 2>&1 &
PIDS+=($!)
ADDR_SOLO=$(wait_addr "$OUT_DIR/shard_solo.log")
echo "control shard at $ADDR_SOLO"

"$BIN" shard-client \
  --shards "$ADDR_SOLO" \
  --tenants "$TENANTS" --events "$EVENTS" --n-lr "$N_LR" --seed "$SEED" \
  --out "$OUT_DIR/BENCH_shard_1.json" \
  --shutdown
wait "${PIDS[0]}"
PIDS=()

echo "== floors + cross-shard-count determinism diff =="
python3 tools/bench_check.py validate-shard "$OUT_DIR/BENCH_shard_2.json" \
  --min-migrations 1 --min-shards 2
python3 tools/bench_check.py diff \
  "$OUT_DIR/BENCH_shard_2.json" "$OUT_DIR/BENCH_shard_1.json"
echo "shard_smoke: OK (artifacts in $OUT_DIR)"
