/* perf_mirror.c — a 1:1 C mirror of the rust kernel engine's algorithms
 * (rust/src/kernels/engine.rs) and the fused quantized-replay read path
 * (rust/src/quant/bitpack.rs + coordinator/replay.rs), extended with the
 * true-INT8 frozen-stage path: the i8×i8→i32 pair-interleaved GEMM core,
 * round-to-nearest weight quantization, fixed-point requantization
 * (rust/src/quant/requant.rs), and a MicroNet-32 frozen-pipeline parity
 * + before/after measurement (fake-quant FP32 simulation vs integer).
 *
 * Two jobs:
 *  1. cross-validate the exact blocking/packing/edge logic against the
 *     naive references (same indexing, same tile solver, same micro-tile
 *     padding) on hosts without a rust toolchain — including BIT-EXACT
 *     integer-kernel checks and the ≤1-LSB-per-layer parity of the
 *     integer pipeline against the fake-quant oracle;
 *  2. measure representative before/after numbers for BENCH_kernels.json
 *     / EXPERIMENTS.md §Perf. `cargo bench --bench fig8_kernels` and
 *     `--bench hot_path` regenerate the authoritative numbers wherever
 *     cargo exists.
 *
 * Build:  gcc -O3 -march=native -o perf_mirror perf_mirror.c -lpthread -lm
 * Run:    ./perf_mirror            (correctness + timing report)
 */

#include <math.h>
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#define MR 8
#define NR 8

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}

/* ---- xoshiro-ish deterministic rng (values only need to be varied) ---- */
static uint64_t rng_state = 0x9E3779B97F4A7C15ULL;
static uint64_t rng_u64(void) {
    uint64_t z = (rng_state += 0x9E3779B97F4A7C15ULL);
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
    return z ^ (z >> 31);
}
static float rng_f32(void) { return (float)((rng_u64() >> 11) * (1.0 / 9007199254740992.0)); }
static void fill_rand(float *p, size_t n) {
    for (size_t i = 0; i < n; i++) p[i] = rng_f32() * 2.0f - 1.0f;
}

/* ---- naive references (rust: matmul_*_naive) -------------------------- */
static void naive_fw(const float *x, const float *w, size_t m, size_t k, size_t n, float *out) {
    for (size_t i = 0; i < m; i++)
        for (size_t j = 0; j < n; j++) {
            float acc = 0.0f;
            for (size_t p = 0; p < k; p++) acc += x[i * k + p] * w[p * n + j];
            out[i * n + j] = acc;
        }
}
static void naive_bw_err(const float *g, const float *w, size_t m, size_t k, size_t n, float *dx) {
    for (size_t i = 0; i < m; i++)
        for (size_t p = 0; p < k; p++) {
            float acc = 0.0f;
            for (size_t j = 0; j < n; j++) acc += g[i * n + j] * w[p * n + j];
            dx[i * k + p] = acc;
        }
}
static void naive_bw_grad(const float *x, const float *g, size_t m, size_t k, size_t n, float *dw) {
    for (size_t p = 0; p < k; p++)
        for (size_t j = 0; j < n; j++) {
            float acc = 0.0f;
            for (size_t i = 0; i < m; i++) acc += x[i * k + p] * g[i * n + j];
            dw[p * n + j] = acc;
        }
}

/* ---- the tile solver (rust: simulator/tiling.rs solve_tile) ----------- */
typedef struct { size_t tm, tn, tk; } TileDims;
static size_t tile_floats(size_t tm, size_t tn, size_t tk) { return tm * tk + tk * tn + tm * tn; }
static TileDims solve_tile(size_t m, size_t n, size_t k, size_t l1_bytes) {
    size_t budget = l1_bytes / 2 / 4;
    size_t tk = k, tn = n;
    while (tile_floats(1, tn, tk) > budget && tn > 1) tn = (tn + 1) / 2;
    while (tile_floats(1, tn, tk) > budget && tk > 16) tk = (tk + 1) / 2;
    size_t tm = m;
    while (tile_floats(tm, tn, tk) > budget && tm > 1) tm = (tm + 1) / 2;
    TileDims d = { tm, tn, tk };
    return d;
}

/* ---- panel sources (rust: StridedMat / Im2colMat) --------------------- */
typedef struct {
    const float *data;
    size_t rs, cs;          /* strided source */
    /* im2col source (used when data == NULL is false and im2col != 0) */
    int im2col;
    size_t h, w, c, stride, ho, wo;
} Src;

static inline float src_at(const Src *s, size_t i, size_t j) {
    if (!s->im2col) return s->data[i * s->rs + j * s->cs];
    size_t ox = i % s->wo, t = i / s->wo;
    size_t oy = t % s->ho, bi = t / s->ho;
    size_t ch = j % s->c, t2 = j / s->c;
    size_t kx = t2 % 3, ky = t2 / 3;
    long iy = (long)(oy * s->stride + ky) - 1;
    long ix = (long)(ox * s->stride + kx) - 1;
    if (iy < 0 || ix < 0 || iy >= (long)s->h || ix >= (long)s->w) return 0.0f;
    return s->data[((bi * s->h + (size_t)iy) * s->w + (size_t)ix) * s->c + ch];
}

/* ---- the packed blocked core (rust: gemm_rows) ------------------------ */
static void microkernel(size_t kc, const float *a, const float *b, float acc[MR][NR]) {
    for (size_t p = 0; p < kc; p++) {
        const float *ar = a + p * MR;
        const float *br = b + p * NR;
        for (size_t r = 0; r < MR; r++) {
            float av = ar[r];
            for (size_t c = 0; c < NR; c++) acc[r][c] += av * br[c];
        }
    }
}

static void gemm_rows(const Src *a, const Src *b, size_t row0, size_t rows, size_t n, size_t k,
                      TileDims dims, float *out) {
    size_t tk = dims.tk ? dims.tk : 1;
    size_t tn = dims.tn ? dims.tn : 1;
    size_t bpanels_max = (tn + NR - 1) / NR;
    float *apack = calloc(MR * tk, sizeof(float));
    float *bpack = calloc(tk * bpanels_max * NR, sizeof(float));
    float acc[MR][NR];

    for (size_t n0 = 0; n0 < n; ) {
        size_t nb = tn < n - n0 ? tn : n - n0;
        size_t nb_panels = (nb + NR - 1) / NR;
        for (size_t k0 = 0; k0 < k; ) {
            size_t kb = tk < k - k0 ? tk : k - k0;
            for (size_t jp = 0; jp < nb_panels; jp++) {
                size_t j0 = n0 + jp * NR;
                size_t jw = NR < n0 + nb - j0 ? NR : n0 + nb - j0;
                float *dst = bpack + jp * kb * NR;
                for (size_t p = 0; p < kb; p++) {
                    float *row = dst + p * NR;
                    for (size_t c = 0; c < jw; c++) row[c] = src_at(b, k0 + p, j0 + c);
                    for (size_t c = jw; c < NR; c++) row[c] = 0.0f;
                }
            }
            for (size_t i0 = 0; i0 < rows; i0 += MR) {
                size_t iw = MR < rows - i0 ? MR : rows - i0;
                for (size_t p = 0; p < kb; p++) {
                    float *dst = apack + p * MR;
                    for (size_t r = 0; r < iw; r++) dst[r] = src_at(a, row0 + i0 + r, k0 + p);
                    for (size_t r = iw; r < MR; r++) dst[r] = 0.0f;
                }
                for (size_t jp = 0; jp < nb_panels; jp++) {
                    size_t j0 = n0 + jp * NR;
                    size_t jw = NR < n0 + nb - j0 ? NR : n0 + nb - j0;
                    memset(acc, 0, sizeof(acc));
                    microkernel(kb, apack, bpack + jp * kb * NR, acc);
                    for (size_t r = 0; r < iw; r++) {
                        float *orow = out + (i0 + r) * n + j0;
                        for (size_t c = 0; c < jw; c++) orow[c] += acc[r][c];
                    }
                }
            }
            k0 += kb;
        }
        n0 += nb;
    }
    free(apack);
    free(bpack);
}

typedef struct {
    const Src *a, *b;
    size_t row0, rows, n, k;
    TileDims dims;
    float *out;
} Job;

static void *worker(void *arg) {
    Job *j = arg;
    gemm_rows(j->a, j->b, j->row0, j->rows, j->n, j->k, j->dims, j->out);
    return NULL;
}

static void gemm(const Src *a, const Src *b, size_t m, size_t n, size_t k, int threads,
                 size_t l2_bytes, float *out) {
    memset(out, 0, m * n * sizeof(float));
    if (m == 0 || n == 0 || k == 0) return;
    TileDims dims = solve_tile(m, n, k, l2_bytes);
    size_t panels = (m + MR - 1) / MR;
    size_t t = threads < 1 ? 1 : (size_t)threads;
    if (t > panels) t = panels;
    if (t <= 1) { gemm_rows(a, b, 0, m, n, k, dims, out); return; }
    size_t rows_per = (panels + t - 1) / t * MR;
    Job jobs[64];
    pthread_t tids[64];
    size_t nt = 0, row0 = 0;
    while (row0 < m) {
        size_t rows = rows_per < m - row0 ? rows_per : m - row0;
        jobs[nt] = (Job){ a, b, row0, rows, n, k, dims, out + row0 * n };
        pthread_create(&tids[nt], NULL, worker, &jobs[nt]);
        row0 += rows;
        nt++;
    }
    for (size_t i = 0; i < nt; i++) pthread_join(tids[i], NULL);
}

/* ---- persistent worker pool (mirrors rust/src/exec/ExecPool) ---------- */
/* Workers park on a condvar between fork-joins; a fork publishes the SAME
 * job partition `gemm()` would have spawned threads for, wakes the pool,
 * and the caller claims parts too (help-first, like ExecPool::drive_parts).
 * The partition is a pure function of (rows, threads), so pooled output
 * must be byte-identical to the per-call-spawn path — asserted below. */
#define POOL_MAX 8
typedef struct {
    pthread_mutex_t mu;
    pthread_cond_t work_cv, done_cv;
    Job jobs[64];
    size_t n_jobs, next, done;
    int shutdown;
    pthread_t tids[POOL_MAX];
    int width;
} Pool;

static void *pool_worker(void *arg) {
    Pool *p = arg;
    pthread_mutex_lock(&p->mu);
    for (;;) {
        while (!p->shutdown && p->next >= p->n_jobs)
            pthread_cond_wait(&p->work_cv, &p->mu);
        if (p->shutdown) break;
        while (p->next < p->n_jobs) {
            Job *j = &p->jobs[p->next++];
            pthread_mutex_unlock(&p->mu);
            gemm_rows(j->a, j->b, j->row0, j->rows, j->n, j->k, j->dims, j->out);
            pthread_mutex_lock(&p->mu);
            if (++p->done == p->n_jobs) pthread_cond_broadcast(&p->done_cv);
        }
    }
    pthread_mutex_unlock(&p->mu);
    return NULL;
}

static Pool g_pool;

static void pool_init(int width) {
    g_pool.width = width > POOL_MAX ? POOL_MAX : (width < 1 ? 1 : width);
    pthread_mutex_init(&g_pool.mu, NULL);
    pthread_cond_init(&g_pool.work_cv, NULL);
    pthread_cond_init(&g_pool.done_cv, NULL);
    g_pool.n_jobs = 0;
    g_pool.next = 0;
    g_pool.done = 0;
    g_pool.shutdown = 0;
    for (int i = 0; i < g_pool.width; i++)
        pthread_create(&g_pool.tids[i], NULL, pool_worker, &g_pool);
}

static void pool_shutdown(void) {
    pthread_mutex_lock(&g_pool.mu);
    g_pool.shutdown = 1;
    pthread_cond_broadcast(&g_pool.work_cv);
    pthread_mutex_unlock(&g_pool.mu);
    for (int i = 0; i < g_pool.width; i++) pthread_join(g_pool.tids[i], NULL);
}

/* identical partition + dims to gemm(); only the executors differ */
static void gemm_pooled(const Src *a, const Src *b, size_t m, size_t n, size_t k, int threads,
                        size_t l2_bytes, float *out) {
    memset(out, 0, m * n * sizeof(float));
    if (m == 0 || n == 0 || k == 0) return;
    TileDims dims = solve_tile(m, n, k, l2_bytes);
    size_t panels = (m + MR - 1) / MR;
    size_t t = threads < 1 ? 1 : (size_t)threads;
    if (t > panels) t = panels;
    if (t <= 1) { gemm_rows(a, b, 0, m, n, k, dims, out); return; }
    size_t rows_per = (panels + t - 1) / t * MR;
    pthread_mutex_lock(&g_pool.mu);
    size_t nt = 0, row0 = 0;
    while (row0 < m) {
        size_t rows = rows_per < m - row0 ? rows_per : m - row0;
        g_pool.jobs[nt++] = (Job){ a, b, row0, rows, n, k, dims, out + row0 * n };
        row0 += rows;
    }
    g_pool.n_jobs = nt;
    g_pool.next = 0;
    g_pool.done = 0;
    pthread_cond_broadcast(&g_pool.work_cv);
    while (g_pool.next < g_pool.n_jobs) {
        Job *j = &g_pool.jobs[g_pool.next++];
        pthread_mutex_unlock(&g_pool.mu);
        gemm_rows(j->a, j->b, j->row0, j->rows, j->n, j->k, j->dims, j->out);
        pthread_mutex_lock(&g_pool.mu);
        if (++g_pool.done == g_pool.n_jobs) pthread_cond_broadcast(&g_pool.done_cv);
    }
    while (g_pool.done < g_pool.n_jobs) pthread_cond_wait(&g_pool.done_cv, &g_pool.mu);
    pthread_mutex_unlock(&g_pool.mu);
}

/* pass wrappers matching engine.rs */
static void blocked_fw(const float *x, const float *w, size_t m, size_t k, size_t n, int th,
                       size_t l2, float *out) {
    Src a = { x, k, 1, 0, 0, 0, 0, 0, 0, 0 };
    Src b = { w, n, 1, 0, 0, 0, 0, 0, 0, 0 };
    gemm(&a, &b, m, n, k, th, l2, out);
}
static void blocked_bw_err(const float *g, const float *w, size_t m, size_t k, size_t n, int th,
                           size_t l2, float *out) {
    Src a = { g, n, 1, 0, 0, 0, 0, 0, 0, 0 };
    Src b = { w, 1, n, 0, 0, 0, 0, 0, 0, 0 };
    gemm(&a, &b, m, k, n, th, l2, out);
}
static void blocked_bw_grad(const float *x, const float *g, size_t m, size_t k, size_t n, int th,
                            size_t l2, float *out) {
    Src a = { x, 1, k, 0, 0, 0, 0, 0, 0, 0 };
    Src b = { g, n, 1, 0, 0, 0, 0, 0, 0, 0 };
    gemm(&a, &b, k, n, m, th, l2, out);
}
static void blocked_fw_pooled(const float *x, const float *w, size_t m, size_t k, size_t n,
                              int th, size_t l2, float *out) {
    Src a = { x, k, 1, 0, 0, 0, 0, 0, 0, 0 };
    Src b = { w, n, 1, 0, 0, 0, 0, 0, 0, 0 };
    gemm_pooled(&a, &b, m, n, k, th, l2, out);
}

/* ---- im2col reference + fused conv ------------------------------------ */
static float *im2col3x3(const float *x, size_t b, size_t h, size_t w, size_t c, size_t stride,
                        size_t *rows_out) {
    size_t ho = (h + stride - 1) / stride, wo = (w + stride - 1) / stride;
    size_t cols = 9 * c, rows = b * ho * wo;
    float *out = calloc(rows * cols, sizeof(float));
    for (size_t bi = 0; bi < b; bi++)
        for (size_t oy = 0; oy < ho; oy++)
            for (size_t ox = 0; ox < wo; ox++) {
                size_t row = ((bi * ho + oy) * wo + ox) * cols;
                for (size_t ky = 0; ky < 3; ky++)
                    for (size_t kx = 0; kx < 3; kx++) {
                        long iy = (long)(oy * stride + ky) - 1;
                        long ix = (long)(ox * stride + kx) - 1;
                        if (iy < 0 || ix < 0 || iy >= (long)h || ix >= (long)w) continue;
                        memcpy(out + row + (ky * 3 + kx) * c,
                               x + ((bi * h + (size_t)iy) * w + (size_t)ix) * c,
                               c * sizeof(float));
                    }
            }
    *rows_out = rows;
    return out;
}

static void conv_fused(const float *x, const float *wmat, size_t b, size_t h, size_t w, size_t c,
                       size_t stride, size_t cout, int th, size_t l2, float *out) {
    size_t ho = (h + stride - 1) / stride, wo = (w + stride - 1) / stride;
    Src a = { x, 0, 0, 1, h, w, c, stride, ho, wo };
    Src bm = { wmat, cout, 1, 0, 0, 0, 0, 0, 0, 0 };
    gemm(&a, &bm, b * ho * wo, cout, 9 * c, th, l2, out);
}

/* ---- bitpack + fused dequant (rust: quant/bitpack.rs) ------------------ */
static size_t packed_len(size_t n, unsigned bits) { return (n * bits + 7) / 8; }

static void pack_bits(const uint8_t *codes, size_t n, unsigned bits, uint8_t *out) {
    if (bits == 8) { memcpy(out, codes, n); return; }
    uint32_t acc = 0, nbits = 0;
    size_t byte_i = 0;
    for (size_t i = 0; i < n; i++) {
        acc |= (uint32_t)codes[i] << nbits;
        nbits += bits;
        while (nbits >= 8) { out[byte_i++] = acc & 0xFF; acc >>= 8; nbits -= 8; }
    }
    if (nbits > 0) out[byte_i] = acc & 0xFF;
}

static void unpack_range(const uint8_t *packed, unsigned bits, size_t start, size_t len,
                         uint8_t *out) {
    if (bits == 8) { memcpy(out, packed + start, len); return; }
    uint32_t mask = (1u << bits) - 1;
    size_t bitpos = start * bits;
    for (size_t i = 0; i < len; i++) {
        size_t byte_i = bitpos / 8, off = bitpos % 8;
        uint32_t lo = packed[byte_i] >> off;
        uint32_t hi = off + bits > 8 ? (uint32_t)packed[byte_i + 1] << (8 - off) : 0;
        out[i] = (lo | hi) & mask;
        bitpos += bits;
    }
}

/* mirrors rust unpack_dequant_range: affine-lut contract, convert+scale
 * fast path at Q=8, eight-codes-per-u64 group decode below, scalar tail */
static void unpack_dequant_range(const uint8_t *packed, size_t packed_bytes, unsigned bits,
                                 size_t start, const float lut[256], size_t len, float *out) {
    float scale = lut[1];
    if (bits == 8) {
        const uint8_t *src = packed + start;
        for (size_t i = 0; i < len; i++) out[i] = (float)src[i] * scale;
        return;
    }
    uint32_t mask = (1u << bits) - 1;
    size_t bitpos = start * bits;
    size_t idx = 0;
    if (bitpos % 8 == 0) {
        size_t byte = bitpos / 8;
        while (idx + 8 <= len && byte + 8 <= packed_bytes) {
            uint64_t v;
            memcpy(&v, packed + byte, 8);
            for (unsigned j = 0; j < 8; j++)
                out[idx + j] = (float)((v >> (bits * j)) & mask) * scale;
            idx += 8;
            byte += bits;
            bitpos += 8 * (size_t)bits;
        }
    }
    for (; idx < len; idx++) {
        size_t byte_i = bitpos / 8, off = bitpos % 8;
        uint32_t lo = packed[byte_i] >> off;
        uint32_t hi = off + bits > 8 ? (uint32_t)packed[byte_i + 1] << (8 - off) : 0;
        out[idx] = lut[(lo | hi) & mask];
        bitpos += bits;
    }
}

/* ==== the true-INT8 path (engine.rs i8 section + quant/requant.rs) ====== */

#define MRI 8
#define NRI 32

/* u8 activation-code panel source: strided or im2col (pad -> code 0) */
typedef struct {
    const uint8_t *data;
    size_t rs, cs;
    int im2col;
    size_t h, w, c, stride, ho, wo;
} SrcU8;

static inline uint8_t srcu8_at(const SrcU8 *s, size_t i, size_t j) {
    if (!s->im2col) return s->data[i * s->rs + j * s->cs];
    size_t ox = i % s->wo, t = i / s->wo;
    size_t oy = t % s->ho, bi = t / s->ho;
    size_t ch = j % s->c, t2 = j / s->c;
    size_t kx = t2 % 3, ky = t2 / 3;
    long iy = (long)(oy * s->stride + ky) - 1;
    long ix = (long)(ox * s->stride + kx) - 1;
    if (iy < 0 || ix < 0 || iy >= (long)s->h || ix >= (long)s->w) return 0;
    return s->data[((bi * s->h + (size_t)iy) * s->w + (size_t)ix) * s->c + ch];
}

/* rust microkernel_i8: paired rank-2 update over [kp][MRI][2]/[kp][NRI][2]
 * i16 panels — two MACs per i32 lane (the pmaddwd dataflow) */
static void microkernel_i8(size_t kp, const int16_t *a, const int16_t *b,
                           int32_t acc[MRI][NRI]) {
    for (size_t p = 0; p < kp; p++) {
        const int16_t *ap = a + p * MRI * 2;
        const int16_t *bp = b + p * NRI * 2;
        for (size_t r = 0; r < MRI; r++) {
            int32_t a0 = ap[r * 2], a1 = ap[r * 2 + 1];
            for (size_t c = 0; c < NRI; c++)
                acc[r][c] += a0 * (int32_t)bp[c * 2] + a1 * (int32_t)bp[c * 2 + 1];
        }
    }
}

/* rust microkernel_i8_half: same packed layout, first NRI/2 lanes only —
 * the narrow-N fallback (the stem conv's N=16 would waste half the MACs
 * of the full-width tile) */
static void microkernel_i8_half(size_t kp, const int16_t *a, const int16_t *b,
                                int32_t acc[MRI][NRI]) {
    for (size_t p = 0; p < kp; p++) {
        const int16_t *ap = a + p * MRI * 2;
        const int16_t *bp = b + p * NRI * 2;
        for (size_t r = 0; r < MRI; r++) {
            int32_t a0 = ap[r * 2], a1 = ap[r * 2 + 1];
            for (size_t c = 0; c < NRI / 2; c++)
                acc[r][c] += a0 * (int32_t)bp[c * 2] + a1 * (int32_t)bp[c * 2 + 1];
        }
    }
}

/* rust gemm_i8_rows: one worker's rows, zero-point correction via row sums */
static void gemm_i8_rows(const SrcU8 *a, const int8_t *w, int32_t w_off, size_t row0,
                         size_t rows, size_t n, size_t k, TileDims dims, int32_t *out) {
    size_t tk = dims.tk ? dims.tk : 1;
    size_t tn = dims.tn ? dims.tn : 1;
    size_t kp_max = (tk + 1) / 2;
    int16_t *apack = calloc(kp_max * MRI * 2, 2);
    int16_t *bpack = calloc(kp_max * ((tn + NRI - 1) / NRI) * NRI * 2, 2);
    int32_t acc[MRI][NRI];
    /* zero-point row sums, accumulated DURING the n0 == 0 A-packing
     * pass (each (row, k) element is packed exactly once per n0 block,
     * so the first block's packs see every k) — no second decode of the
     * A source, which matters for the im2col stem */
    int32_t *rowsum = calloc(rows, 4);

    for (size_t n0 = 0; n0 < n; ) {
        size_t nb = tn < n - n0 ? tn : n - n0;
        size_t nbp = (nb + NRI - 1) / NRI;
        for (size_t k0 = 0; k0 < k; ) {
            size_t kb = tk < k - k0 ? tk : k - k0;
            size_t kp = (kb + 1) / 2;
            for (size_t jp = 0; jp < nbp; jp++) {
                size_t j0 = n0 + jp * NRI;
                size_t jw = NRI < n0 + nb - j0 ? NRI : n0 + nb - j0;
                int16_t *dst = bpack + jp * kp * NRI * 2;
                memset(dst, 0, kp * NRI * 2 * 2);
                for (size_t p = 0; p < kb; p++) {
                    size_t half = p & 1;
                    int16_t *d = dst + (p >> 1) * NRI * 2;
                    for (size_t cc = 0; cc < jw; cc++)
                        d[cc * 2 + half] = w[(k0 + p) * n + j0 + cc];
                }
            }
            for (size_t i0 = 0; i0 < rows; i0 += MRI) {
                size_t iw = MRI < rows - i0 ? MRI : rows - i0;
                memset(apack, 0, kp * MRI * 2 * 2);
                for (size_t p = 0; p < kb; p++) {
                    size_t half = p & 1;
                    int16_t *d = apack + (p >> 1) * MRI * 2;
                    for (size_t r = 0; r < iw; r++)
                        d[r * 2 + half] = srcu8_at(a, row0 + i0 + r, k0 + p);
                }
                if (n0 == 0)
                    for (size_t p = 0; p < kb; p++) {
                        const int16_t *d = apack + (p >> 1) * MRI * 2 + (p & 1);
                        for (size_t r = 0; r < iw; r++) rowsum[i0 + r] += d[r * 2];
                    }
                for (size_t jp = 0; jp < nbp; jp++) {
                    size_t j0 = n0 + jp * NRI;
                    size_t jw = NRI < n0 + nb - j0 ? NRI : n0 + nb - j0;
                    memset(acc, 0, sizeof(acc));
                    if (jw <= NRI / 2)
                        microkernel_i8_half(kp, apack, bpack + jp * kp * NRI * 2, acc);
                    else
                        microkernel_i8(kp, apack, bpack + jp * kp * NRI * 2, acc);
                    for (size_t r = 0; r < iw; r++) {
                        int32_t *orow = out + (i0 + r) * n + j0;
                        for (size_t cc = 0; cc < jw; cc++) orow[cc] += acc[r][cc];
                    }
                }
            }
            k0 += kb;
        }
        n0 += nb;
    }
    if (w_off != 0)
        for (size_t r = 0; r < rows; r++) {
            int32_t base = w_off * rowsum[r];
            for (size_t j = 0; j < n; j++) out[r * n + j] += base;
        }
    free(apack);
    free(bpack);
    free(rowsum);
}

typedef struct {
    const SrcU8 *a;
    const int8_t *w;
    int32_t w_off;
    size_t row0, rows, n, k;
    TileDims dims;
    int32_t *out;
} JobI8;

static void *worker_i8(void *arg) {
    JobI8 *j = arg;
    gemm_i8_rows(j->a, j->w, j->w_off, j->row0, j->rows, j->n, j->k, j->dims, j->out);
    return NULL;
}

static void gemm_i8(const SrcU8 *a, const int8_t *w, int32_t w_off, size_t m, size_t n,
                    size_t k, int threads, size_t l2, int32_t *out) {
    memset(out, 0, m * n * 4);
    if (m == 0 || n == 0 || k == 0) return;
    TileDims dims = solve_tile(m, n, k, l2);
    size_t panels = (m + MRI - 1) / MRI;
    size_t t = threads < 1 ? 1 : (size_t)threads;
    if (t > panels) t = panels;
    if (t <= 1) { gemm_i8_rows(a, w, w_off, 0, m, n, k, dims, out); return; }
    size_t rows_per = (panels + t - 1) / t * MRI;
    JobI8 jobs[64];
    pthread_t tids[64];
    size_t nt = 0, row0 = 0;
    while (row0 < m) {
        size_t rows = rows_per < m - row0 ? rows_per : m - row0;
        jobs[nt] = (JobI8){ a, w, w_off, row0, rows, n, k, dims, out + row0 * n };
        pthread_create(&tids[nt], NULL, worker_i8, &jobs[nt]);
        row0 += rows;
        nt++;
    }
    for (size_t i = 0; i < nt; i++) pthread_join(tids[i], NULL);
}

static void naive_i8(const uint8_t *x, const int8_t *w, int32_t w_off, size_t m, size_t k,
                     size_t n, int32_t *out) {
    for (size_t i = 0; i < m; i++)
        for (size_t j = 0; j < n; j++) {
            int32_t acc = 0;
            for (size_t p = 0; p < k; p++)
                acc += (int32_t)x[i * k + p] * ((int32_t)w[p * n + j] + w_off);
            out[i * n + j] = acc;
        }
}

/* rust dw_rows_i8 (single worker covers the whole output here) */
static void dw_i8(const uint8_t *x, const int8_t *kern, int32_t w_off, size_t b, size_t h,
                  size_t w, size_t c, size_t stride, int32_t *out) {
    size_t ho = (h + stride - 1) / stride, wo = (w + stride - 1) / stride;
    memset(out, 0, b * ho * wo * c * 4);
    int32_t *tap = calloc(c, 4);
    for (size_t bi = 0; bi < b; bi++)
        for (size_t oy = 0; oy < ho; oy++)
            for (size_t ox = 0; ox < wo; ox++) {
                int32_t *dst = out + ((bi * ho + oy) * wo + ox) * c;
                memset(tap, 0, c * 4);
                for (size_t ky = 0; ky < 3; ky++) {
                    long iy = (long)(oy * stride + ky) - 1;
                    if (iy < 0 || iy >= (long)h) continue;
                    for (size_t kx = 0; kx < 3; kx++) {
                        long ix = (long)(ox * stride + kx) - 1;
                        if (ix < 0 || ix >= (long)w) continue;
                        const uint8_t *src = x + ((bi * h + (size_t)iy) * w + (size_t)ix) * c;
                        const int8_t *kf = kern + (ky * 3 + kx) * c;
                        for (size_t ch = 0; ch < c; ch++) {
                            dst[ch] += (int32_t)src[ch] * (int32_t)kf[ch];
                            tap[ch] += src[ch];
                        }
                    }
                }
                for (size_t ch = 0; ch < c; ch++) dst[ch] += w_off * tap[ch];
            }
    free(tap);
}

/* f32 depthwise (pad=1), the fake-quant pipeline's DW layer */
static void dw_f32(const float *x, const float *kern, size_t b, size_t h, size_t w, size_t c,
                   size_t stride, float *out) {
    size_t ho = (h + stride - 1) / stride, wo = (w + stride - 1) / stride;
    memset(out, 0, b * ho * wo * c * 4);
    for (size_t bi = 0; bi < b; bi++)
        for (size_t oy = 0; oy < ho; oy++)
            for (size_t ox = 0; ox < wo; ox++) {
                float *dst = out + ((bi * ho + oy) * wo + ox) * c;
                for (size_t ky = 0; ky < 3; ky++) {
                    long iy = (long)(oy * stride + ky) - 1;
                    if (iy < 0 || iy >= (long)h) continue;
                    for (size_t kx = 0; kx < 3; kx++) {
                        long ix = (long)(ox * stride + kx) - 1;
                        if (ix < 0 || ix >= (long)w) continue;
                        const float *src = x + ((bi * h + (size_t)iy) * w + (size_t)ix) * c;
                        const float *kf = kern + (ky * 3 + kx) * c;
                        for (size_t ch = 0; ch < c; ch++) dst[ch] += src[ch] * kf[ch];
                    }
                }
            }
}

/* ---- quant/requant.rs mirror ------------------------------------------ */

static float act_scale(float a_max) {
    float s = a_max / 255.0f;
    return s > 1e-12f ? s : 1e-12f;
}

static void quant_acts(const float *x, size_t n, float a_max, uint8_t *out) {
    float inv = 1.0f / act_scale(a_max);
    for (size_t i = 0; i < n; i++) {
        float q = floorf(x[i] * inv);
        out[i] = q < 0 ? 0 : (q > 255 ? 255 : (uint8_t)q);
    }
}

static void dequant_acts(const uint8_t *q, size_t n, float a_max, float *out) {
    float s = act_scale(a_max);
    for (size_t i = 0; i < n; i++) out[i] = (float)q[i] * s;
}

static void fq_act(float *x, size_t n, float a_max) {
    float s = act_scale(a_max), inv = 1.0f / s;
    for (size_t i = 0; i < n; i++) {
        float q = floorf(x[i] * inv);
        q = q < 0 ? 0 : (q > 255 ? 255 : q);
        x[i] = q * s;
    }
}

/* round-to-nearest full-range affine weight quantization (requant.rs) */
typedef struct { int8_t *codes; int32_t off; float scale; } QWeights;

static QWeights quant_weights_i8(const float *w, size_t n) {
    float w_min = 0, w_max = 0;
    for (size_t i = 0; i < n; i++) {
        if (w[i] < w_min) w_min = w[i];
        if (w[i] > w_max) w_max = w[i];
    }
    float scale = (w_max - w_min) / 255.0f;
    if (scale < 1e-12f) scale = 1e-12f;
    float lo = floorf(w_min / scale);
    QWeights q = { malloc(n), (int32_t)lo + 128, scale };
    for (size_t i = 0; i < n; i++) {
        float v = floorf(w[i] / scale + 0.5f);
        if (v < lo) v = lo;
        if (v > lo + 255.0f) v = lo + 255.0f;
        q.codes[i] = (int8_t)(v - lo - 128.0f);
    }
    return q;
}

static void dequant_weights(const QWeights *q, size_t n, float *out) {
    for (size_t i = 0; i < n; i++) out[i] = (float)((int32_t)q->codes[i] + q->off) * q->scale;
}

/* fixed-point multiplier+shift (requant.rs::Requant) */
typedef struct { int64_t mult; int shift; } Requant;

static Requant requant_from_scale(double s) {
    Requant r = { 0, 0 };
    if (!(s > 0) || s != s || s > 1e300) return r;
    double mant = s;
    int exp = 0;
    while (mant >= 1.0) { mant *= 0.5; exp++; }
    while (mant < 0.5) { mant *= 2.0; exp--; }
    int64_t mult = (int64_t)(mant * 2147483648.0 + 0.5);
    if (mult == (1LL << 31)) { mult = 1LL << 30; exp++; }
    r.mult = mult;
    r.shift = 31 - exp;
    return r;
}

static inline uint8_t requant_q(Requant r, int32_t acc, uint32_t levels) {
    if (acc <= 0) return 0;
    int64_t prod = (int64_t)acc * r.mult;
    int64_t v;
    if (r.shift >= 64) v = 0;
    else if (r.shift >= 0) v = prod >> r.shift;
    else v = prod << (-r.shift < 62 ? -r.shift : 62);
    if (v < 0) v = 0;
    if (v > (int64_t)levels) v = levels;
    return (uint8_t)v;
}

/* ---- the MicroNet-32 frozen pipeline, both paths ----------------------- */

typedef struct { int kind; size_t cin, cout, stride; } Layer; /* 0=c3,1=dw,2=pw */
#define N_LAYERS 15
static const Layer ARCH[N_LAYERS] = {
    {0, 3, 16, 2},  {1, 16, 16, 1},  {2, 16, 32, 1},  {1, 32, 32, 2},  {2, 32, 64, 1},
    {1, 64, 64, 1}, {2, 64, 64, 1},  {1, 64, 64, 2},  {2, 64, 128, 1}, {1, 128, 128, 1},
    {2, 128, 128, 1}, {1, 128, 128, 2}, {2, 128, 256, 1}, {1, 256, 256, 1}, {2, 256, 256, 1},
};
#define INPUT_HW 32

static size_t wlen(const Layer *l) {
    return l->kind == 0 ? 9 * l->cin * l->cout : (l->kind == 1 ? 9 * l->cin : l->cin * l->cout);
}

/* f32 conv of one layer (blocked engine), y must hold b*ho*wo*cout */
static void conv_f32(const Layer *l, const float *w, const float *x, size_t b, size_t hw,
                     int threads, size_t l2, float *y) {
    size_t ho = (hw + l->stride - 1) / l->stride;
    if (l->kind == 0) {
        conv_fused(x, w, b, hw, hw, l->cin, l->stride, l->cout, threads, l2, y);
    } else if (l->kind == 1) {
        dw_f32(x, w, b, hw, hw, l->cin, l->stride, y);
    } else {
        blocked_fw(x, w, b * hw * hw, l->cin, l->cout, threads, l2, y);
    }
    (void)ho;
}

/* integer conv of one layer */
static void conv_int(const Layer *l, const QWeights *qw, const uint8_t *q, size_t b,
                     size_t hw, int threads, size_t l2, int32_t *acc) {
    size_t ho = (hw + l->stride - 1) / l->stride;
    if (l->kind == 0) {
        SrcU8 a = { q, 0, 0, 1, hw, hw, l->cin, l->stride, ho, ho };
        gemm_i8(&a, qw->codes, qw->off, b * ho * ho, l->cout, 9 * l->cin, threads, l2, acc);
    } else if (l->kind == 1) {
        dw_i8(q, qw->codes, qw->off, b, hw, hw, l->cin, l->stride, acc);
    } else {
        SrcU8 a = { q, l->cin, 1, 0, 0, 0, 0, 0, 0, 0 };
        gemm_i8(&a, qw->codes, qw->off, b * hw * hw, l->cout, l->cin, threads, l2, acc);
    }
}

typedef struct {
    float *w[N_LAYERS];        /* normalized master weights */
    float *w_grid[N_LAYERS];   /* fake-quant grid (dequantized codes) */
    QWeights qw[N_LAYERS];
    Requant rq[N_LAYERS];
    float a_max[N_LAYERS];
} Frozen;

/* seeded He-ish init + layer-wise standardization + PTQ calibration,
 * the same recipe runtime/native.rs uses (approximate weights, exact
 * quantization arithmetic — parity numbers transfer) */
static void frozen_init(Frozen *f, size_t probes, int threads, size_t l2) {
    size_t hw = INPUT_HW;
    float *x = malloc(probes * hw * hw * 3 * 4);
    for (size_t i = 0; i < probes * hw * hw * 3; i++) x[i] = rng_f32();
    for (int li = 0; li < N_LAYERS; li++) {
        const Layer *l = &ARCH[li];
        size_t n = wlen(l);
        f->w[li] = malloc(n * 4);
        double std = l->kind == 0 ? sqrt(2.0 / (9.0 * l->cin))
                   : (l->kind == 1 ? sqrt(2.0 / 9.0) : sqrt(2.0 / l->cin));
        for (size_t i = 0; i < n; i++)
            f->w[li][i] = (rng_f32() * 2.0f - 1.0f) * 1.7320508f * (float)std;
        size_t ho = (hw + l->stride - 1) / l->stride;
        float *y = malloc(probes * ho * ho * l->cout * 4);
        conv_f32(l, f->w[li], x, probes, hw, threads, l2, y);
        size_t yn = probes * ho * ho * l->cout;
        double sum = 0, sum2 = 0;
        for (size_t i = 0; i < yn; i++) {
            float v = y[i] > 0 ? y[i] : 0;
            y[i] = v;
            sum += v;
            sum2 += (double)v * v;
        }
        double mean = sum / yn;
        double sd = sqrt(sum2 / yn - mean * mean);
        float inv = 1.0f / (sd > 1e-6 ? (float)sd : 1e-6f);
        for (size_t i = 0; i < n; i++) f->w[li][i] *= inv;
        for (size_t i = 0; i < yn; i++) y[i] *= inv;
        free(x);
        x = y;
        hw = ho;
    }
    free(x);
    /* quantize weights, then calibrate a_max progressively (fake-quant) */
    for (int li = 0; li < N_LAYERS; li++) {
        size_t n = wlen(&ARCH[li]);
        f->qw[li] = quant_weights_i8(f->w[li], n);
        f->w_grid[li] = malloc(n * 4);
        dequant_weights(&f->qw[li], n, f->w_grid[li]);
    }
    hw = INPUT_HW;
    x = malloc(probes * hw * hw * 3 * 4);
    for (size_t i = 0; i < probes * hw * hw * 3; i++) x[i] = rng_f32();
    fq_act(x, probes * hw * hw * 3, 1.0f);
    for (int li = 0; li < N_LAYERS; li++) {
        const Layer *l = &ARCH[li];
        size_t ho = (hw + l->stride - 1) / l->stride;
        float *y = malloc(probes * ho * ho * l->cout * 4);
        conv_f32(l, f->w_grid[li], x, probes, hw, 1, 256 * 1024, y);
        size_t yn = probes * ho * ho * l->cout;
        float mx = 0;
        for (size_t i = 0; i < yn; i++) {
            float v = y[i] > 0 ? y[i] : 0;
            y[i] = v;
            if (v > mx) mx = v;
        }
        f->a_max[li] = mx > 1e-3f ? mx : 1e-3f;
        fq_act(y, yn, f->a_max[li]);
        free(x);
        x = y;
        hw = ho;
    }
    free(x);
    float in_a = 1.0f;
    for (int li = 0; li < N_LAYERS; li++) {
        double s = (double)act_scale(in_a) * f->qw[li].scale / act_scale(f->a_max[li]);
        f->rq[li] = requant_from_scale(s);
        in_a = f->a_max[li];
    }
}

/* run the fake-quant f32 frozen prefix, returning codes per layer `upto` */
static uint8_t *frozen_fq_codes(const Frozen *f, const float *images, size_t b, int upto,
                                int threads, size_t l2, size_t *out_n) {
    size_t hw = INPUT_HW;
    size_t n = b * hw * hw * 3;
    float *x = malloc(n * 4);
    memcpy(x, images, n * 4);
    fq_act(x, n, 1.0f);
    for (int li = 0; li < upto; li++) {
        const Layer *l = &ARCH[li];
        size_t ho = (hw + l->stride - 1) / l->stride;
        size_t yn = b * ho * ho * l->cout;
        float *y = malloc(yn * 4);
        conv_f32(l, f->w_grid[li], x, b, hw, threads, l2, y);
        for (size_t i = 0; i < yn; i++) y[i] = y[i] > 0 ? y[i] : 0;
        fq_act(y, yn, f->a_max[li]);
        free(x);
        x = y;
        n = yn;
        hw = ho;
    }
    /* recover the codes of the (on-grid) fq output: round, not floor —
     * x[i] is exactly code * S, so this is lossless */
    float last_a = upto == 0 ? 1.0f : f->a_max[upto - 1];
    float inv = 1.0f / act_scale(last_a);
    uint8_t *codes = malloc(n);
    for (size_t i = 0; i < n; i++) codes[i] = (uint8_t)floorf(x[i] * inv + 0.5f);
    free(x);
    *out_n = n;
    return codes;
}

/* run the integer frozen prefix, returning codes per layer `upto` */
static uint8_t *frozen_int_codes(const Frozen *f, const float *images, size_t b, int upto,
                                 int threads, size_t l2, size_t *out_n) {
    size_t hw = INPUT_HW;
    size_t n = b * hw * hw * 3;
    uint8_t *q = malloc(n);
    quant_acts(images, n, 1.0f, q);
    for (int li = 0; li < upto; li++) {
        const Layer *l = &ARCH[li];
        size_t ho = (hw + l->stride - 1) / l->stride;
        size_t yn = b * ho * ho * l->cout;
        int32_t *acc = malloc(yn * 4);
        conv_int(l, &f->qw[li], q, b, hw, threads, l2, acc);
        uint8_t *qy = malloc(yn);
        for (size_t i = 0; i < yn; i++) qy[i] = requant_q(f->rq[li], acc[i], 255);
        free(acc);
        free(q);
        q = qy;
        n = yn;
        hw = ho;
    }
    *out_n = n;
    return q;
}

/* ---- helpers ----------------------------------------------------------- */
static float max_abs_diff(const float *a, const float *b, size_t n) {
    float worst = 0.0f;
    for (size_t i = 0; i < n; i++) {
        float d = fabsf(a[i] - b[i]);
        if (d > worst) worst = d;
    }
    return worst;
}

static int cmp_double(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

static double median_time(void (*fn)(void *), void *arg, int reps) {
    double ts[64];
    for (int i = 0; i < reps; i++) {
        double t0 = now_s();
        fn(arg);
        ts[i] = now_s() - t0;
    }
    qsort(ts, reps, sizeof(double), cmp_double);
    return ts[reps / 2];
}

/* timing thunks */
typedef struct { const float *x, *w, *g; size_t m, k, n; int th; size_t l2; float *out; } MmArgs;
static void t_naive_fw(void *p) { MmArgs *a = p; naive_fw(a->x, a->w, a->m, a->k, a->n, a->out); }
static void t_blocked_fw(void *p) { MmArgs *a = p; blocked_fw(a->x, a->w, a->m, a->k, a->n, a->th, a->l2, a->out); }
static void t_naive_be(void *p) { MmArgs *a = p; naive_bw_err(a->g, a->w, a->m, a->k, a->n, a->out); }
static void t_blocked_be(void *p) { MmArgs *a = p; blocked_bw_err(a->g, a->w, a->m, a->k, a->n, a->th, a->l2, a->out); }
static void t_naive_bg(void *p) { MmArgs *a = p; naive_bw_grad(a->x, a->g, a->m, a->k, a->n, a->out); }
static void t_blocked_bg(void *p) { MmArgs *a = p; blocked_bw_grad(a->x, a->g, a->m, a->k, a->n, a->th, a->l2, a->out); }

/* spawn-overhead bench: many small-GEMM calls per rep (single call is µs) */
typedef struct { const float *x, *w; size_t m, k, n; int th; size_t l2; float *out; int calls; int pooled; } PoolArgs;
static void t_small_gemm(void *p) {
    PoolArgs *a = p;
    for (int i = 0; i < a->calls; i++) {
        if (a->pooled) blocked_fw_pooled(a->x, a->w, a->m, a->k, a->n, a->th, a->l2, a->out);
        else blocked_fw(a->x, a->w, a->m, a->k, a->n, a->th, a->l2, a->out);
    }
}

typedef struct {
    const uint8_t *arena; size_t arena_bytes; unsigned bits; const float *lut;
    size_t elems, n_lr; uint8_t *scratch; float *out; int fused;
} ReplayArgs;
static void t_replay(void *p) {
    ReplayArgs *a = p;
    for (int i = 0; i < 56; i++) {
        size_t slot = rng_u64() % a->n_lr;
        float *dst = a->out + (size_t)i * a->elems;
        if (a->fused) {
            unpack_dequant_range(a->arena, a->arena_bytes, a->bits, slot * a->elems, a->lut,
                                 a->elems, dst);
        } else {
            /* the pre-rework path: unpack into a code scratch, then
             * dequantize — which rebuilt its 256-entry LUT per call */
            unpack_range(a->arena, a->bits, slot * a->elems, a->elems, a->scratch);
            float l[256];
            float s = a->lut[1];
            for (int q = 0; q < 256; q++) l[q] = (float)q * s;
            for (size_t e = 0; e < a->elems; e++) dst[e] = l[a->scratch[e]];
        }
    }
}

int main(void) {
    const size_t L2 = 256 * 1024;
    int fails = 0;

    /* ================= correctness: ragged shapes, all passes ========= */
    printf("== correctness sweep (blocked vs naive, incl. ragged shapes) ==\n");
    size_t shapes[][3] = { {1,1,1}, {7,5,3}, {8,8,8}, {9,17,33}, {64,64,64},
                           {65,63,62}, {127,1,61}, {1,128,7}, {40,40,40}, {130,70,90} };
    for (size_t s = 0; s < sizeof(shapes) / sizeof(shapes[0]); s++) {
        size_t m = shapes[s][0], k = shapes[s][1], n = shapes[s][2];
        float *x = malloc(m * k * 4), *w = malloc(k * n * 4), *g = malloc(m * n * 4);
        fill_rand(x, m * k); fill_rand(w, k * n); fill_rand(g, m * n);
        float *r1 = malloc(m * n * 4), *r2 = malloc(m * n * 4);
        float *e1 = malloc(m * k * 4), *e2 = malloc(m * k * 4);
        float *d1 = malloc(k * n * 4), *d2 = malloc(k * n * 4);
        for (int th = 1; th <= 8; th *= 2) {
            for (size_t l2 = 4096; l2 <= L2; l2 *= 64) {
                naive_fw(x, w, m, k, n, r1);
                blocked_fw(x, w, m, k, n, th, l2, r2);
                float d = max_abs_diff(r1, r2, m * n);
                if (d >= 1e-3f * k) { printf("FAIL fw %zux%zux%zu th=%d: %g\n", m, k, n, th, d); fails++; }
                naive_bw_err(g, w, m, k, n, e1);
                blocked_bw_err(g, w, m, k, n, th, l2, e2);
                d = max_abs_diff(e1, e2, m * k);
                if (d >= 1e-3f * n) { printf("FAIL bw-err %zux%zux%zu th=%d: %g\n", m, k, n, th, d); fails++; }
                naive_bw_grad(x, g, m, k, n, d1);
                blocked_bw_grad(x, g, m, k, n, th, l2, d2);
                d = max_abs_diff(d1, d2, k * n);
                if (d >= 1e-3f * m) { printf("FAIL bw-grad %zux%zux%zu th=%d: %g\n", m, k, n, th, d); fails++; }
            }
        }
        /* determinism across thread counts (bit-exact) */
        blocked_fw(x, w, m, k, n, 1, 4096, r1);
        blocked_fw(x, w, m, k, n, 8, 4096, r2);
        if (memcmp(r1, r2, m * n * 4) != 0) { printf("FAIL determinism %zu\n", s); fails++; }
        free(x); free(w); free(g); free(r1); free(r2); free(e1); free(e2); free(d1); free(d2);
    }

    /* fused conv vs im2col+naive */
    {
        size_t b = 2, h = 13, w = 11, c = 5, cout = 7;
        for (size_t stride = 1; stride <= 2; stride++) {
            float *x = malloc(b * h * w * c * 4), *wm = malloc(9 * c * cout * 4);
            fill_rand(x, b * h * w * c); fill_rand(wm, 9 * c * cout);
            size_t rows;
            float *cols = im2col3x3(x, b, h, w, c, stride, &rows);
            float *ref = malloc(rows * cout * 4), *fus = malloc(rows * cout * 4);
            naive_fw(cols, wm, rows, 9 * c, cout, ref);
            conv_fused(x, wm, b, h, w, c, stride, cout, 2, 4096, fus);
            float d = max_abs_diff(ref, fus, rows * cout);
            if (d >= 1e-3f * 9 * c) { printf("FAIL conv fused stride=%zu: %g\n", stride, d); fails++; }
            free(x); free(wm); free(cols); free(ref); free(fus);
        }
    }

    /* fused dequant vs two-pass: bit-exact */
    {
        size_t elems = 1024, n_lr = 256;
        for (unsigned bits = 1; bits <= 8; bits++) {
            size_t ncodes = n_lr * elems;
            uint8_t *codes = malloc(ncodes);
            for (size_t i = 0; i < ncodes; i++) codes[i] = rng_u64() & ((1u << bits) - 1);
            uint8_t *arena = calloc(packed_len(ncodes, bits), 1);
            pack_bits(codes, ncodes, bits, arena);
            float lut[256] = {0};
            for (unsigned q = 0; q < (1u << bits); q++) lut[q] = q * (1.0f / ((1u << bits) - 1));
            uint8_t *scratch = malloc(elems);
            float *a = malloc(elems * 4), *bb = malloc(elems * 4);
            for (size_t slot = 0; slot < n_lr; slot += 37) {
                unpack_dequant_range(arena, packed_len(ncodes, bits), bits, slot * elems, lut,
                                     elems, a);
                unpack_range(arena, bits, slot * elems, elems, scratch);
                for (size_t e = 0; e < elems; e++) bb[e] = lut[scratch[e]];
                if (memcmp(a, bb, elems * 4) != 0) { printf("FAIL fused dequant bits=%u\n", bits); fails++; break; }
                for (size_t e = 0; e < elems; e++) if (scratch[e] != codes[slot * elems + e]) { printf("FAIL unpack bits=%u\n", bits); fails++; break; }
            }
            free(codes); free(arena); free(scratch); free(a); free(bb);
        }
    }

    /* ---- integer kernels: BIT-EXACT vs the naive i8 oracle ---------- */
    {
        size_t shapes_i[][3] = { {1,1,1}, {7,5,3}, {9,17,33}, {64,64,64}, {65,63,62},
                                 {130,27,40}, {1,128,7}, {33,70,90} };
        for (size_t s = 0; s < sizeof(shapes_i) / sizeof(shapes_i[0]); s++) {
            size_t m = shapes_i[s][0], k = shapes_i[s][1], n = shapes_i[s][2];
            uint8_t *x = malloc(m * k);
            int8_t *w = malloc(k * n);
            for (size_t i = 0; i < m * k; i++) x[i] = rng_u64() & 255;
            for (size_t i = 0; i < k * n; i++) w[i] = (int8_t)(rng_u64() & 255);
            int32_t *ref = malloc(m * n * 4), *got = malloc(m * n * 4);
            for (int off = -127; off <= 128; off += 85) {
                naive_i8(x, w, off, m, k, n, ref);
                for (int th = 1; th <= 4; th *= 2) {
                    for (size_t l2 = 4096; l2 <= L2; l2 *= 64) {
                        SrcU8 a = { x, k, 1, 0, 0, 0, 0, 0, 0, 0 };
                        gemm_i8(&a, w, off, m, n, k, th, l2, got);
                        if (memcmp(ref, got, m * n * 4)) {
                            printf("FAIL i8 fw %zux%zux%zu th=%d off=%d\n", m, k, n, th, off);
                            fails++;
                        }
                    }
                }
            }
            free(x); free(w); free(ref); free(got);
        }
        /* depthwise i8 vs a per-element recomputation through naive taps */
        size_t b = 2, h = 9, w = 7, c = 5;
        uint8_t *x = malloc(b * h * w * c);
        int8_t *kern = malloc(9 * c);
        for (size_t i = 0; i < b * h * w * c; i++) x[i] = rng_u64() & 255;
        for (size_t i = 0; i < 9 * c; i++) kern[i] = (int8_t)(rng_u64() & 255);
        for (size_t stride = 1; stride <= 2; stride++) {
            size_t ho = (h + stride - 1) / stride, wo = (w + stride - 1) / stride;
            int32_t *got = malloc(b * ho * wo * c * 4);
            dw_i8(x, kern, -37, b, h, w, c, stride, got);
            int bad = 0;
            for (size_t bi = 0; bi < b && !bad; bi++)
                for (size_t oy = 0; oy < ho && !bad; oy++)
                    for (size_t ox = 0; ox < wo && !bad; ox++)
                        for (size_t ch = 0; ch < c && !bad; ch++) {
                            int32_t acc = 0;
                            for (size_t ky = 0; ky < 3; ky++)
                                for (size_t kx = 0; kx < 3; kx++) {
                                    long iy = (long)(oy * stride + ky) - 1;
                                    long ix = (long)(ox * stride + kx) - 1;
                                    if (iy < 0 || ix < 0 || iy >= (long)h || ix >= (long)w)
                                        continue;
                                    acc += (int32_t)x[((bi * h + iy) * w + ix) * c + ch]
                                         * ((int32_t)kern[(ky * 3 + kx) * c + ch] - 37);
                                }
                            if (got[((bi * ho + oy) * wo + ox) * c + ch] != acc) bad = 1;
                        }
            if (bad) { printf("FAIL i8 depthwise stride=%zu\n", stride); fails++; }
            free(got);
        }
        free(x); free(kern);
    }

    /* requant vs real floor in the code range */
    {
        for (int t = 0; t < 4000; t++) {
            double s = pow(10.0, (double)(rng_u64() % 1200) / 100.0 - 9.0);
            Requant r = requant_from_scale(s);
            double cap = 1e6 / s;
            if (cap > 1073741824.0) cap = 1073741824.0;
            if (cap < 1) cap = 1;
            int32_t acc = (int32_t)(rng_u64() % (uint64_t)cap);
            int64_t real = (int64_t)floor((double)acc * s);
            int64_t got = acc <= 0 ? 0 : (((int64_t)acc * r.mult) >> (r.shift < 63 ? r.shift : 63));
            if (r.shift >= 64) got = 0;
            if (llabs(real - got) > 1) {
                printf("FAIL requant s=%g acc=%d real=%lld got=%lld\n", s, acc,
                       (long long)real, (long long)got);
                fails++;
            }
        }
    }

    /* ---- frozen-pipeline parity: integer vs fake-quant oracle -------- */
    Frozen fz;
    rng_state = 0x9E3779B97F4A7C15ULL; /* reseed for reproducibility */
    frozen_init(&fz, 16, 2, L2);
    {
        size_t b = 8;
        size_t n_img = b * INPUT_HW * INPUT_HW * 3;
        float *images = malloc(n_img * 4);
        for (size_t i = 0; i < n_img; i++) images[i] = rng_f32();
        printf("== frozen-pipeline parity (integer vs fake-quant f32, batch %zu) ==\n", b);
        /* per-layer, resynced on the integer codes: the rust unit test's
         * exact structure (≤1 LSB) is asserted there; here we track the
         * END-TO-END drift the int8_parity integration test bounds */
        for (int upto = 1; upto <= N_LAYERS; upto++) {
            size_t n1, n2;
            uint8_t *qa = frozen_int_codes(&fz, images, b, upto, 2, L2, &n1);
            uint8_t *qb = frozen_fq_codes(&fz, images, b, upto, 2, L2, &n2);
            if (n1 != n2) { printf("FAIL parity size l=%d\n", upto); fails++; }
            int worst = 0;
            size_t ndiff = 0;
            for (size_t i = 0; i < n1; i++) {
                int d = abs((int)qa[i] - (int)qb[i]);
                if (d > worst) worst = d;
                ndiff += d != 0;
            }
            if (upto == 1 && worst > 1) {
                printf("FAIL layer-1 parity: worst %d\n", worst);
                fails++;
            }
            printf("  l=%2d: %7zu codes, %6zu differ (%.3f%%), worst %d\n", upto, n1, ndiff,
                   100.0 * ndiff / n1, worst);
            free(qa);
            free(qb);
        }
        free(images);
    }

    printf("correctness: %s\n\n", fails ? "FAILURES (see above)" : "all checks passed");
    if (fails) return 1;

    /* ================= timing ========================================= */
    printf("== timing (median of 9) ==\n");
    size_t m = 512, k = 512, n = 512;
    float *x = malloc(m * k * 4), *w = malloc(k * n * 4), *g = malloc(m * n * 4);
    fill_rand(x, m * k); fill_rand(w, k * n); fill_rand(g, m * n);
    float *out = malloc(m * n * 4);
    MmArgs a = { x, w, g, m, k, n, 1, L2, out };
    double t_naive = median_time(t_naive_fw, &a, 9);
    a.th = 1;
    double t_b1 = median_time(t_blocked_fw, &a, 9);
    a.th = 2;
    double t_b2 = median_time(t_blocked_fw, &a, 9);
    a.th = 8;
    double t_b8 = median_time(t_blocked_fw, &a, 9);
    double gmac = (double)m * k * n * 1e-9;
    printf("matmul_fw 512^3   naive      %8.2f ms (%5.2f GMAC/s)\n", t_naive * 1e3, gmac / t_naive);
    printf("matmul_fw 512^3   blocked x1 %8.2f ms (%5.2f GMAC/s)  speedup %.2fx\n", t_b1 * 1e3, gmac / t_b1, t_naive / t_b1);
    printf("matmul_fw 512^3   blocked x2 %8.2f ms (%5.2f GMAC/s)  speedup %.2fx\n", t_b2 * 1e3, gmac / t_b2, t_naive / t_b2);
    printf("matmul_fw 512^3   blocked x8 %8.2f ms (%5.2f GMAC/s)  speedup %.2fx\n", t_b8 * 1e3, gmac / t_b8, t_naive / t_b8);

    a.th = 2;
    double tn_be = median_time(t_naive_be, &a, 9);
    double tb_be = median_time(t_blocked_be, &a, 9);
    double tn_bg = median_time(t_naive_bg, &a, 9);
    double tb_bg = median_time(t_blocked_bg, &a, 9);
    printf("matmul_bw_err     naive %8.2f ms | blocked x2 %8.2f ms  speedup %.2fx\n", tn_be * 1e3, tb_be * 1e3, tn_be / tb_be);
    printf("matmul_bw_grad    naive %8.2f ms | blocked x2 %8.2f ms  speedup %.2fx\n", tn_bg * 1e3, tb_bg * 1e3, tn_bg / tb_bg);

    /* replay path */
    size_t elems = 1024, n_lr = 256;
    for (unsigned bits = 8; bits >= 6; bits--) {
        size_t ncodes = n_lr * elems;
        uint8_t *codes = malloc(ncodes);
        for (size_t i = 0; i < ncodes; i++) codes[i] = rng_u64() & ((1u << bits) - 1);
        uint8_t *arena = calloc(packed_len(ncodes, bits), 1);
        pack_bits(codes, ncodes, bits, arena);
        float lut[256] = {0};
        for (unsigned q = 0; q < (1u << bits); q++) lut[q] = q * (1.0f / ((1u << bits) - 1));
        uint8_t *scratch = malloc(elems);
        float *rout = malloc(56 * elems * 4);
        ReplayArgs ra = { arena, packed_len(ncodes, bits), bits, lut, elems, n_lr,
                          scratch, rout, 0 };
        /* many reps: single op is microseconds */
        double t0 = now_s();
        for (int i = 0; i < 2000; i++) t_replay(&ra);
        double two_pass = (now_s() - t0) / 2000.0;
        ra.fused = 1;
        t0 = now_s();
        for (int i = 0; i < 2000; i++) t_replay(&ra);
        double fused = (now_s() - t0) / 2000.0;
        printf("replay_sample56_u%u  two-pass %7.1f us | fused %7.1f us  speedup %.2fx\n",
               bits, two_pass * 1e6, fused * 1e6, two_pass / fused);
        free(codes); free(arena); free(scratch); free(rout);
    }

    /* ---- true-INT8 frozen path timing -------------------------------- */
    printf("\n== true-INT8 frozen path (before = fake-quant f32, after = integer) ==\n");
    {
        /* GEMM core, 512^3 (the PW22 geometry) */
        size_t mm = 512, kk = 512, nn = 512;
        uint8_t *xi = malloc(mm * kk);
        int8_t *wi = malloc(kk * nn);
        int32_t *oi = malloc(mm * nn * 4);
        for (size_t i = 0; i < mm * kk; i++) xi[i] = rng_u64() & 255;
        for (size_t i = 0; i < kk * nn; i++) wi[i] = (int8_t)(rng_u64() & 255);
        SrcU8 ai = { xi, kk, 1, 0, 0, 0, 0, 0, 0, 0 };
        double bi1 = 1e9, bi2 = 1e9;
        for (int rep = 0; rep < 9; rep++) {
            double t0 = now_s();
            gemm_i8(&ai, wi, -3, mm, nn, kk, 1, L2, oi);
            double t = now_s() - t0;
            if (t < bi1) bi1 = t;
            t0 = now_s();
            gemm_i8(&ai, wi, -3, mm, nn, kk, 2, L2, oi);
            t = now_s() - t0;
            if (t < bi2) bi2 = t;
        }
        double gmac = (double)mm * kk * nn * 1e-9;
        printf("matmul_fw_i8 512^3 x1 %8.2f ms (%5.2f GMAC/s)  vs f32 blocked x1 %.2fx\n",
               bi1 * 1e3, gmac / bi1, t_b1 / bi1);
        printf("matmul_fw_i8 512^3 x2 %8.2f ms (%5.2f GMAC/s)  vs f32 blocked x2 %.2fx\n",
               bi2 * 1e3, gmac / bi2, t_b2 / bi2);
        free(xi); free(wi); free(oi);

        /* whole frozen prefixes at batch 8, both paths, 2 threads */
        size_t b = 8;
        size_t n_img = b * INPUT_HW * INPUT_HW * 3;
        float *images = malloc(n_img * 4);
        for (size_t i = 0; i < n_img; i++) images[i] = rng_f32();
        int splits[3] = { 9, 13, 15 };
        for (int si = 0; si < 3; si++) {
            int l = splits[si];
            size_t nn1, nn2;
            double t_fq = 1e9, t_int = 1e9;
            for (int rep = 0; rep < 7; rep++) {
                double t0 = now_s();
                uint8_t *q = frozen_fq_codes(&fz, images, b, l, 2, L2, &nn1);
                double t = now_s() - t0;
                if (t < t_fq) t_fq = t;
                free(q);
                t0 = now_s();
                q = frozen_int_codes(&fz, images, b, l, 2, L2, &nn2);
                t = now_s() - t0;
                if (t < t_int) t_int = t;
                free(q);
            }
            printf("frozen_forward l=%2d b=8: fake-quant %7.2f ms | int8 %7.2f ms  speedup %.2fx\n",
                   l, t_fq * 1e3, t_int * 1e3, t_fq / t_int);
        }
        /* one depthwise layer in isolation (memory-bound end) */
        {
            size_t db = 8, dh = 8, dc = 128;
            size_t xn = db * dh * dh * dc;
            float *xf = malloc(xn * 4), *kf = malloc(9 * dc * 4), *yf = malloc(xn * 4);
            uint8_t *xq = malloc(xn);
            int8_t *kq = malloc(9 * dc);
            int32_t *yi = malloc(xn * 4);
            for (size_t i = 0; i < xn; i++) { xq[i] = rng_u64() & 255; xf[i] = xq[i] / 255.0f; }
            for (size_t i = 0; i < 9 * dc; i++) { kq[i] = (int8_t)(rng_u64() & 255); kf[i] = kq[i] / 128.0f; }
            double tf = 1e9, ti = 1e9;
            for (int rep = 0; rep < 50; rep++) {
                double t0 = now_s();
                dw_f32(xf, kf, db, dh, dh, dc, 1, yf);
                double t = now_s() - t0;
                if (t < tf) tf = t;
                t0 = now_s();
                dw_i8(xq, kq, -7, db, dh, dh, dc, 1, yi);
                t = now_s() - t0;
                if (t < ti) ti = t;
            }
            printf("depthwise 8x8x128 b=8:   f32 %7.3f ms | int8 %7.3f ms  speedup %.2fx\n",
                   tf * 1e3, ti * 1e3, tf / ti);
            free(xf); free(kf); free(yf); free(xq); free(kq); free(yi);
        }
        free(images);
    }

    /* ---- persistent pool vs per-call thread spawn -------------------- */
    /* The exec-refactor mirror: the SAME row partition executed by parked
     * pool workers vs freshly-spawned threads, on a GEMM small enough
     * that spawn overhead dominates (the frozen stage's steady state is
     * thousands of such dispatches). Bit-identity is a hard gate. */
    printf("\n== persistent pool vs per-call thread spawn (small GEMM, x4) ==\n");
    {
        size_t sm = 64, sk = 64, sn = 64;
        int th = 4;
        float *sx = malloc(sm * sk * 4), *sw = malloc(sk * sn * 4);
        float *so = malloc(sm * sn * 4), *sp = malloc(sm * sn * 4);
        fill_rand(sx, sm * sk);
        fill_rand(sw, sk * sn);
        pool_init(th);
        blocked_fw(sx, sw, sm, sk, sn, th, L2, so);
        blocked_fw_pooled(sx, sw, sm, sk, sn, th, L2, sp);
        int bit_identical = memcmp(so, sp, sm * sn * 4) == 0;
        if (!bit_identical) {
            printf("FAIL pooled small GEMM differs from spawned\n");
            pool_shutdown();
            return 1;
        }
        PoolArgs pa = { sx, sw, sm, sk, sn, th, L2, so, 400, 0 };
        double spawn_us = median_time(t_small_gemm, &pa, 5) / pa.calls * 1e6;
        pa.out = sp;
        pa.pooled = 1;
        double pooled_us = median_time(t_small_gemm, &pa, 5) / pa.calls * 1e6;
        printf("small_gemm 64^3 x4  spawn-per-call %7.1f us | pooled %7.1f us"
               "  speedup %.2fx  bit-identical yes\n",
               spawn_us, pooled_us, spawn_us / pooled_us);
        pool_shutdown();
        free(sx); free(sw); free(so); free(sp);
    }

    free(x); free(w); free(g); free(out);
    return 0;
}
