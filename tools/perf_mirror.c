/* perf_mirror.c — a 1:1 C mirror of the rust kernel engine's algorithms
 * (rust/src/kernels/engine.rs) and the fused quantized-replay read path
 * (rust/src/quant/bitpack.rs + coordinator/replay.rs).
 *
 * Two jobs:
 *  1. cross-validate the exact blocking/packing/edge logic against the
 *     naive references (same indexing, same tile solver, same micro-tile
 *     padding) on hosts without a rust toolchain;
 *  2. measure representative before/after numbers for BENCH_kernels.json
 *     / EXPERIMENTS.md §Perf. `cargo bench --bench fig8_kernels` and
 *     `--bench hot_path` regenerate the authoritative numbers wherever
 *     cargo exists.
 *
 * Build:  gcc -O3 -march=native -o perf_mirror perf_mirror.c -lpthread -lm
 * Run:    ./perf_mirror            (correctness + timing report)
 */

#include <math.h>
#include <pthread.h>
#include <stdint.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

#define MR 8
#define NR 8

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec * 1e-9;
}

/* ---- xoshiro-ish deterministic rng (values only need to be varied) ---- */
static uint64_t rng_state = 0x9E3779B97F4A7C15ULL;
static uint64_t rng_u64(void) {
    uint64_t z = (rng_state += 0x9E3779B97F4A7C15ULL);
    z = (z ^ (z >> 30)) * 0xBF58476D1CE4E5B9ULL;
    z = (z ^ (z >> 27)) * 0x94D049BB133111EBULL;
    return z ^ (z >> 31);
}
static float rng_f32(void) { return (float)((rng_u64() >> 11) * (1.0 / 9007199254740992.0)); }
static void fill_rand(float *p, size_t n) {
    for (size_t i = 0; i < n; i++) p[i] = rng_f32() * 2.0f - 1.0f;
}

/* ---- naive references (rust: matmul_*_naive) -------------------------- */
static void naive_fw(const float *x, const float *w, size_t m, size_t k, size_t n, float *out) {
    for (size_t i = 0; i < m; i++)
        for (size_t j = 0; j < n; j++) {
            float acc = 0.0f;
            for (size_t p = 0; p < k; p++) acc += x[i * k + p] * w[p * n + j];
            out[i * n + j] = acc;
        }
}
static void naive_bw_err(const float *g, const float *w, size_t m, size_t k, size_t n, float *dx) {
    for (size_t i = 0; i < m; i++)
        for (size_t p = 0; p < k; p++) {
            float acc = 0.0f;
            for (size_t j = 0; j < n; j++) acc += g[i * n + j] * w[p * n + j];
            dx[i * k + p] = acc;
        }
}
static void naive_bw_grad(const float *x, const float *g, size_t m, size_t k, size_t n, float *dw) {
    for (size_t p = 0; p < k; p++)
        for (size_t j = 0; j < n; j++) {
            float acc = 0.0f;
            for (size_t i = 0; i < m; i++) acc += x[i * k + p] * g[i * n + j];
            dw[p * n + j] = acc;
        }
}

/* ---- the tile solver (rust: simulator/tiling.rs solve_tile) ----------- */
typedef struct { size_t tm, tn, tk; } TileDims;
static size_t tile_floats(size_t tm, size_t tn, size_t tk) { return tm * tk + tk * tn + tm * tn; }
static TileDims solve_tile(size_t m, size_t n, size_t k, size_t l1_bytes) {
    size_t budget = l1_bytes / 2 / 4;
    size_t tk = k, tn = n;
    while (tile_floats(1, tn, tk) > budget && tn > 1) tn = (tn + 1) / 2;
    while (tile_floats(1, tn, tk) > budget && tk > 16) tk = (tk + 1) / 2;
    size_t tm = m;
    while (tile_floats(tm, tn, tk) > budget && tm > 1) tm = (tm + 1) / 2;
    TileDims d = { tm, tn, tk };
    return d;
}

/* ---- panel sources (rust: StridedMat / Im2colMat) --------------------- */
typedef struct {
    const float *data;
    size_t rs, cs;          /* strided source */
    /* im2col source (used when data == NULL is false and im2col != 0) */
    int im2col;
    size_t h, w, c, stride, ho, wo;
} Src;

static inline float src_at(const Src *s, size_t i, size_t j) {
    if (!s->im2col) return s->data[i * s->rs + j * s->cs];
    size_t ox = i % s->wo, t = i / s->wo;
    size_t oy = t % s->ho, bi = t / s->ho;
    size_t ch = j % s->c, t2 = j / s->c;
    size_t kx = t2 % 3, ky = t2 / 3;
    long iy = (long)(oy * s->stride + ky) - 1;
    long ix = (long)(ox * s->stride + kx) - 1;
    if (iy < 0 || ix < 0 || iy >= (long)s->h || ix >= (long)s->w) return 0.0f;
    return s->data[((bi * s->h + (size_t)iy) * s->w + (size_t)ix) * s->c + ch];
}

/* ---- the packed blocked core (rust: gemm_rows) ------------------------ */
static void microkernel(size_t kc, const float *a, const float *b, float acc[MR][NR]) {
    for (size_t p = 0; p < kc; p++) {
        const float *ar = a + p * MR;
        const float *br = b + p * NR;
        for (size_t r = 0; r < MR; r++) {
            float av = ar[r];
            for (size_t c = 0; c < NR; c++) acc[r][c] += av * br[c];
        }
    }
}

static void gemm_rows(const Src *a, const Src *b, size_t row0, size_t rows, size_t n, size_t k,
                      TileDims dims, float *out) {
    size_t tk = dims.tk ? dims.tk : 1;
    size_t tn = dims.tn ? dims.tn : 1;
    size_t bpanels_max = (tn + NR - 1) / NR;
    float *apack = calloc(MR * tk, sizeof(float));
    float *bpack = calloc(tk * bpanels_max * NR, sizeof(float));
    float acc[MR][NR];

    for (size_t n0 = 0; n0 < n; ) {
        size_t nb = tn < n - n0 ? tn : n - n0;
        size_t nb_panels = (nb + NR - 1) / NR;
        for (size_t k0 = 0; k0 < k; ) {
            size_t kb = tk < k - k0 ? tk : k - k0;
            for (size_t jp = 0; jp < nb_panels; jp++) {
                size_t j0 = n0 + jp * NR;
                size_t jw = NR < n0 + nb - j0 ? NR : n0 + nb - j0;
                float *dst = bpack + jp * kb * NR;
                for (size_t p = 0; p < kb; p++) {
                    float *row = dst + p * NR;
                    for (size_t c = 0; c < jw; c++) row[c] = src_at(b, k0 + p, j0 + c);
                    for (size_t c = jw; c < NR; c++) row[c] = 0.0f;
                }
            }
            for (size_t i0 = 0; i0 < rows; i0 += MR) {
                size_t iw = MR < rows - i0 ? MR : rows - i0;
                for (size_t p = 0; p < kb; p++) {
                    float *dst = apack + p * MR;
                    for (size_t r = 0; r < iw; r++) dst[r] = src_at(a, row0 + i0 + r, k0 + p);
                    for (size_t r = iw; r < MR; r++) dst[r] = 0.0f;
                }
                for (size_t jp = 0; jp < nb_panels; jp++) {
                    size_t j0 = n0 + jp * NR;
                    size_t jw = NR < n0 + nb - j0 ? NR : n0 + nb - j0;
                    memset(acc, 0, sizeof(acc));
                    microkernel(kb, apack, bpack + jp * kb * NR, acc);
                    for (size_t r = 0; r < iw; r++) {
                        float *orow = out + (i0 + r) * n + j0;
                        for (size_t c = 0; c < jw; c++) orow[c] += acc[r][c];
                    }
                }
            }
            k0 += kb;
        }
        n0 += nb;
    }
    free(apack);
    free(bpack);
}

typedef struct {
    const Src *a, *b;
    size_t row0, rows, n, k;
    TileDims dims;
    float *out;
} Job;

static void *worker(void *arg) {
    Job *j = arg;
    gemm_rows(j->a, j->b, j->row0, j->rows, j->n, j->k, j->dims, j->out);
    return NULL;
}

static void gemm(const Src *a, const Src *b, size_t m, size_t n, size_t k, int threads,
                 size_t l2_bytes, float *out) {
    memset(out, 0, m * n * sizeof(float));
    if (m == 0 || n == 0 || k == 0) return;
    TileDims dims = solve_tile(m, n, k, l2_bytes);
    size_t panels = (m + MR - 1) / MR;
    size_t t = threads < 1 ? 1 : (size_t)threads;
    if (t > panels) t = panels;
    if (t <= 1) { gemm_rows(a, b, 0, m, n, k, dims, out); return; }
    size_t rows_per = (panels + t - 1) / t * MR;
    Job jobs[64];
    pthread_t tids[64];
    size_t nt = 0, row0 = 0;
    while (row0 < m) {
        size_t rows = rows_per < m - row0 ? rows_per : m - row0;
        jobs[nt] = (Job){ a, b, row0, rows, n, k, dims, out + row0 * n };
        pthread_create(&tids[nt], NULL, worker, &jobs[nt]);
        row0 += rows;
        nt++;
    }
    for (size_t i = 0; i < nt; i++) pthread_join(tids[i], NULL);
}

/* pass wrappers matching engine.rs */
static void blocked_fw(const float *x, const float *w, size_t m, size_t k, size_t n, int th,
                       size_t l2, float *out) {
    Src a = { x, k, 1, 0, 0, 0, 0, 0, 0, 0 };
    Src b = { w, n, 1, 0, 0, 0, 0, 0, 0, 0 };
    gemm(&a, &b, m, n, k, th, l2, out);
}
static void blocked_bw_err(const float *g, const float *w, size_t m, size_t k, size_t n, int th,
                           size_t l2, float *out) {
    Src a = { g, n, 1, 0, 0, 0, 0, 0, 0, 0 };
    Src b = { w, 1, n, 0, 0, 0, 0, 0, 0, 0 };
    gemm(&a, &b, m, k, n, th, l2, out);
}
static void blocked_bw_grad(const float *x, const float *g, size_t m, size_t k, size_t n, int th,
                            size_t l2, float *out) {
    Src a = { x, 1, k, 0, 0, 0, 0, 0, 0, 0 };
    Src b = { g, n, 1, 0, 0, 0, 0, 0, 0, 0 };
    gemm(&a, &b, k, n, m, th, l2, out);
}

/* ---- im2col reference + fused conv ------------------------------------ */
static float *im2col3x3(const float *x, size_t b, size_t h, size_t w, size_t c, size_t stride,
                        size_t *rows_out) {
    size_t ho = (h + stride - 1) / stride, wo = (w + stride - 1) / stride;
    size_t cols = 9 * c, rows = b * ho * wo;
    float *out = calloc(rows * cols, sizeof(float));
    for (size_t bi = 0; bi < b; bi++)
        for (size_t oy = 0; oy < ho; oy++)
            for (size_t ox = 0; ox < wo; ox++) {
                size_t row = ((bi * ho + oy) * wo + ox) * cols;
                for (size_t ky = 0; ky < 3; ky++)
                    for (size_t kx = 0; kx < 3; kx++) {
                        long iy = (long)(oy * stride + ky) - 1;
                        long ix = (long)(ox * stride + kx) - 1;
                        if (iy < 0 || ix < 0 || iy >= (long)h || ix >= (long)w) continue;
                        memcpy(out + row + (ky * 3 + kx) * c,
                               x + ((bi * h + (size_t)iy) * w + (size_t)ix) * c,
                               c * sizeof(float));
                    }
            }
    *rows_out = rows;
    return out;
}

static void conv_fused(const float *x, const float *wmat, size_t b, size_t h, size_t w, size_t c,
                       size_t stride, size_t cout, int th, size_t l2, float *out) {
    size_t ho = (h + stride - 1) / stride, wo = (w + stride - 1) / stride;
    Src a = { x, 0, 0, 1, h, w, c, stride, ho, wo };
    Src bm = { wmat, cout, 1, 0, 0, 0, 0, 0, 0, 0 };
    gemm(&a, &bm, b * ho * wo, cout, 9 * c, th, l2, out);
}

/* ---- bitpack + fused dequant (rust: quant/bitpack.rs) ------------------ */
static size_t packed_len(size_t n, unsigned bits) { return (n * bits + 7) / 8; }

static void pack_bits(const uint8_t *codes, size_t n, unsigned bits, uint8_t *out) {
    if (bits == 8) { memcpy(out, codes, n); return; }
    uint32_t acc = 0, nbits = 0;
    size_t byte_i = 0;
    for (size_t i = 0; i < n; i++) {
        acc |= (uint32_t)codes[i] << nbits;
        nbits += bits;
        while (nbits >= 8) { out[byte_i++] = acc & 0xFF; acc >>= 8; nbits -= 8; }
    }
    if (nbits > 0) out[byte_i] = acc & 0xFF;
}

static void unpack_range(const uint8_t *packed, unsigned bits, size_t start, size_t len,
                         uint8_t *out) {
    if (bits == 8) { memcpy(out, packed + start, len); return; }
    uint32_t mask = (1u << bits) - 1;
    size_t bitpos = start * bits;
    for (size_t i = 0; i < len; i++) {
        size_t byte_i = bitpos / 8, off = bitpos % 8;
        uint32_t lo = packed[byte_i] >> off;
        uint32_t hi = off + bits > 8 ? (uint32_t)packed[byte_i + 1] << (8 - off) : 0;
        out[i] = (lo | hi) & mask;
        bitpos += bits;
    }
}

/* mirrors rust unpack_dequant_range: affine-lut contract, convert+scale
 * fast path at Q=8, eight-codes-per-u64 group decode below, scalar tail */
static void unpack_dequant_range(const uint8_t *packed, size_t packed_bytes, unsigned bits,
                                 size_t start, const float lut[256], size_t len, float *out) {
    float scale = lut[1];
    if (bits == 8) {
        const uint8_t *src = packed + start;
        for (size_t i = 0; i < len; i++) out[i] = (float)src[i] * scale;
        return;
    }
    uint32_t mask = (1u << bits) - 1;
    size_t bitpos = start * bits;
    size_t idx = 0;
    if (bitpos % 8 == 0) {
        size_t byte = bitpos / 8;
        while (idx + 8 <= len && byte + 8 <= packed_bytes) {
            uint64_t v;
            memcpy(&v, packed + byte, 8);
            for (unsigned j = 0; j < 8; j++)
                out[idx + j] = (float)((v >> (bits * j)) & mask) * scale;
            idx += 8;
            byte += bits;
            bitpos += 8 * (size_t)bits;
        }
    }
    for (; idx < len; idx++) {
        size_t byte_i = bitpos / 8, off = bitpos % 8;
        uint32_t lo = packed[byte_i] >> off;
        uint32_t hi = off + bits > 8 ? (uint32_t)packed[byte_i + 1] << (8 - off) : 0;
        out[idx] = lut[(lo | hi) & mask];
        bitpos += bits;
    }
}

/* ---- helpers ----------------------------------------------------------- */
static float max_abs_diff(const float *a, const float *b, size_t n) {
    float worst = 0.0f;
    for (size_t i = 0; i < n; i++) {
        float d = fabsf(a[i] - b[i]);
        if (d > worst) worst = d;
    }
    return worst;
}

static int cmp_double(const void *a, const void *b) {
    double x = *(const double *)a, y = *(const double *)b;
    return (x > y) - (x < y);
}

static double median_time(void (*fn)(void *), void *arg, int reps) {
    double ts[64];
    for (int i = 0; i < reps; i++) {
        double t0 = now_s();
        fn(arg);
        ts[i] = now_s() - t0;
    }
    qsort(ts, reps, sizeof(double), cmp_double);
    return ts[reps / 2];
}

/* timing thunks */
typedef struct { const float *x, *w, *g; size_t m, k, n; int th; size_t l2; float *out; } MmArgs;
static void t_naive_fw(void *p) { MmArgs *a = p; naive_fw(a->x, a->w, a->m, a->k, a->n, a->out); }
static void t_blocked_fw(void *p) { MmArgs *a = p; blocked_fw(a->x, a->w, a->m, a->k, a->n, a->th, a->l2, a->out); }
static void t_naive_be(void *p) { MmArgs *a = p; naive_bw_err(a->g, a->w, a->m, a->k, a->n, a->out); }
static void t_blocked_be(void *p) { MmArgs *a = p; blocked_bw_err(a->g, a->w, a->m, a->k, a->n, a->th, a->l2, a->out); }
static void t_naive_bg(void *p) { MmArgs *a = p; naive_bw_grad(a->x, a->g, a->m, a->k, a->n, a->out); }
static void t_blocked_bg(void *p) { MmArgs *a = p; blocked_bw_grad(a->x, a->g, a->m, a->k, a->n, a->th, a->l2, a->out); }

typedef struct {
    const uint8_t *arena; size_t arena_bytes; unsigned bits; const float *lut;
    size_t elems, n_lr; uint8_t *scratch; float *out; int fused;
} ReplayArgs;
static void t_replay(void *p) {
    ReplayArgs *a = p;
    for (int i = 0; i < 56; i++) {
        size_t slot = rng_u64() % a->n_lr;
        float *dst = a->out + (size_t)i * a->elems;
        if (a->fused) {
            unpack_dequant_range(a->arena, a->arena_bytes, a->bits, slot * a->elems, a->lut,
                                 a->elems, dst);
        } else {
            /* the pre-rework path: unpack into a code scratch, then
             * dequantize — which rebuilt its 256-entry LUT per call */
            unpack_range(a->arena, a->bits, slot * a->elems, a->elems, a->scratch);
            float l[256];
            float s = a->lut[1];
            for (int q = 0; q < 256; q++) l[q] = (float)q * s;
            for (size_t e = 0; e < a->elems; e++) dst[e] = l[a->scratch[e]];
        }
    }
}

int main(void) {
    const size_t L2 = 256 * 1024;
    int fails = 0;

    /* ================= correctness: ragged shapes, all passes ========= */
    printf("== correctness sweep (blocked vs naive, incl. ragged shapes) ==\n");
    size_t shapes[][3] = { {1,1,1}, {7,5,3}, {8,8,8}, {9,17,33}, {64,64,64},
                           {65,63,62}, {127,1,61}, {1,128,7}, {40,40,40}, {130,70,90} };
    for (size_t s = 0; s < sizeof(shapes) / sizeof(shapes[0]); s++) {
        size_t m = shapes[s][0], k = shapes[s][1], n = shapes[s][2];
        float *x = malloc(m * k * 4), *w = malloc(k * n * 4), *g = malloc(m * n * 4);
        fill_rand(x, m * k); fill_rand(w, k * n); fill_rand(g, m * n);
        float *r1 = malloc(m * n * 4), *r2 = malloc(m * n * 4);
        float *e1 = malloc(m * k * 4), *e2 = malloc(m * k * 4);
        float *d1 = malloc(k * n * 4), *d2 = malloc(k * n * 4);
        for (int th = 1; th <= 8; th *= 2) {
            for (size_t l2 = 4096; l2 <= L2; l2 *= 64) {
                naive_fw(x, w, m, k, n, r1);
                blocked_fw(x, w, m, k, n, th, l2, r2);
                float d = max_abs_diff(r1, r2, m * n);
                if (d >= 1e-3f * k) { printf("FAIL fw %zux%zux%zu th=%d: %g\n", m, k, n, th, d); fails++; }
                naive_bw_err(g, w, m, k, n, e1);
                blocked_bw_err(g, w, m, k, n, th, l2, e2);
                d = max_abs_diff(e1, e2, m * k);
                if (d >= 1e-3f * n) { printf("FAIL bw-err %zux%zux%zu th=%d: %g\n", m, k, n, th, d); fails++; }
                naive_bw_grad(x, g, m, k, n, d1);
                blocked_bw_grad(x, g, m, k, n, th, l2, d2);
                d = max_abs_diff(d1, d2, k * n);
                if (d >= 1e-3f * m) { printf("FAIL bw-grad %zux%zux%zu th=%d: %g\n", m, k, n, th, d); fails++; }
            }
        }
        /* determinism across thread counts (bit-exact) */
        blocked_fw(x, w, m, k, n, 1, 4096, r1);
        blocked_fw(x, w, m, k, n, 8, 4096, r2);
        if (memcmp(r1, r2, m * n * 4) != 0) { printf("FAIL determinism %zu\n", s); fails++; }
        free(x); free(w); free(g); free(r1); free(r2); free(e1); free(e2); free(d1); free(d2);
    }

    /* fused conv vs im2col+naive */
    {
        size_t b = 2, h = 13, w = 11, c = 5, cout = 7;
        for (size_t stride = 1; stride <= 2; stride++) {
            float *x = malloc(b * h * w * c * 4), *wm = malloc(9 * c * cout * 4);
            fill_rand(x, b * h * w * c); fill_rand(wm, 9 * c * cout);
            size_t rows;
            float *cols = im2col3x3(x, b, h, w, c, stride, &rows);
            float *ref = malloc(rows * cout * 4), *fus = malloc(rows * cout * 4);
            naive_fw(cols, wm, rows, 9 * c, cout, ref);
            conv_fused(x, wm, b, h, w, c, stride, cout, 2, 4096, fus);
            float d = max_abs_diff(ref, fus, rows * cout);
            if (d >= 1e-3f * 9 * c) { printf("FAIL conv fused stride=%zu: %g\n", stride, d); fails++; }
            free(x); free(wm); free(cols); free(ref); free(fus);
        }
    }

    /* fused dequant vs two-pass: bit-exact */
    {
        size_t elems = 1024, n_lr = 256;
        for (unsigned bits = 1; bits <= 8; bits++) {
            size_t ncodes = n_lr * elems;
            uint8_t *codes = malloc(ncodes);
            for (size_t i = 0; i < ncodes; i++) codes[i] = rng_u64() & ((1u << bits) - 1);
            uint8_t *arena = calloc(packed_len(ncodes, bits), 1);
            pack_bits(codes, ncodes, bits, arena);
            float lut[256] = {0};
            for (unsigned q = 0; q < (1u << bits); q++) lut[q] = q * (1.0f / ((1u << bits) - 1));
            uint8_t *scratch = malloc(elems);
            float *a = malloc(elems * 4), *bb = malloc(elems * 4);
            for (size_t slot = 0; slot < n_lr; slot += 37) {
                unpack_dequant_range(arena, packed_len(ncodes, bits), bits, slot * elems, lut,
                                     elems, a);
                unpack_range(arena, bits, slot * elems, elems, scratch);
                for (size_t e = 0; e < elems; e++) bb[e] = lut[scratch[e]];
                if (memcmp(a, bb, elems * 4) != 0) { printf("FAIL fused dequant bits=%u\n", bits); fails++; break; }
                for (size_t e = 0; e < elems; e++) if (scratch[e] != codes[slot * elems + e]) { printf("FAIL unpack bits=%u\n", bits); fails++; break; }
            }
            free(codes); free(arena); free(scratch); free(a); free(bb);
        }
    }

    printf("correctness: %s\n\n", fails ? "FAILURES (see above)" : "all checks passed");
    if (fails) return 1;

    /* ================= timing ========================================= */
    printf("== timing (median of 9) ==\n");
    size_t m = 512, k = 512, n = 512;
    float *x = malloc(m * k * 4), *w = malloc(k * n * 4), *g = malloc(m * n * 4);
    fill_rand(x, m * k); fill_rand(w, k * n); fill_rand(g, m * n);
    float *out = malloc(m * n * 4);
    MmArgs a = { x, w, g, m, k, n, 1, L2, out };
    double t_naive = median_time(t_naive_fw, &a, 9);
    a.th = 1;
    double t_b1 = median_time(t_blocked_fw, &a, 9);
    a.th = 2;
    double t_b2 = median_time(t_blocked_fw, &a, 9);
    a.th = 8;
    double t_b8 = median_time(t_blocked_fw, &a, 9);
    double gmac = (double)m * k * n * 1e-9;
    printf("matmul_fw 512^3   naive      %8.2f ms (%5.2f GMAC/s)\n", t_naive * 1e3, gmac / t_naive);
    printf("matmul_fw 512^3   blocked x1 %8.2f ms (%5.2f GMAC/s)  speedup %.2fx\n", t_b1 * 1e3, gmac / t_b1, t_naive / t_b1);
    printf("matmul_fw 512^3   blocked x2 %8.2f ms (%5.2f GMAC/s)  speedup %.2fx\n", t_b2 * 1e3, gmac / t_b2, t_naive / t_b2);
    printf("matmul_fw 512^3   blocked x8 %8.2f ms (%5.2f GMAC/s)  speedup %.2fx\n", t_b8 * 1e3, gmac / t_b8, t_naive / t_b8);

    a.th = 2;
    double tn_be = median_time(t_naive_be, &a, 9);
    double tb_be = median_time(t_blocked_be, &a, 9);
    double tn_bg = median_time(t_naive_bg, &a, 9);
    double tb_bg = median_time(t_blocked_bg, &a, 9);
    printf("matmul_bw_err     naive %8.2f ms | blocked x2 %8.2f ms  speedup %.2fx\n", tn_be * 1e3, tb_be * 1e3, tn_be / tb_be);
    printf("matmul_bw_grad    naive %8.2f ms | blocked x2 %8.2f ms  speedup %.2fx\n", tn_bg * 1e3, tb_bg * 1e3, tn_bg / tb_bg);

    /* replay path */
    size_t elems = 1024, n_lr = 256;
    for (unsigned bits = 8; bits >= 6; bits--) {
        size_t ncodes = n_lr * elems;
        uint8_t *codes = malloc(ncodes);
        for (size_t i = 0; i < ncodes; i++) codes[i] = rng_u64() & ((1u << bits) - 1);
        uint8_t *arena = calloc(packed_len(ncodes, bits), 1);
        pack_bits(codes, ncodes, bits, arena);
        float lut[256] = {0};
        for (unsigned q = 0; q < (1u << bits); q++) lut[q] = q * (1.0f / ((1u << bits) - 1));
        uint8_t *scratch = malloc(elems);
        float *rout = malloc(56 * elems * 4);
        ReplayArgs ra = { arena, packed_len(ncodes, bits), bits, lut, elems, n_lr,
                          scratch, rout, 0 };
        /* many reps: single op is microseconds */
        double t0 = now_s();
        for (int i = 0; i < 2000; i++) t_replay(&ra);
        double two_pass = (now_s() - t0) / 2000.0;
        ra.fused = 1;
        t0 = now_s();
        for (int i = 0; i < 2000; i++) t_replay(&ra);
        double fused = (now_s() - t0) / 2000.0;
        printf("replay_sample56_u%u  two-pass %7.1f us | fused %7.1f us  speedup %.2fx\n",
               bits, two_pass * 1e6, fused * 1e6, two_pass / fused);
        free(codes); free(arena); free(scratch); free(rout);
    }

    free(x); free(w); free(g); free(out);
    return 0;
}
