#!/usr/bin/env python3
"""Measurement mirror of the sharded serving layer (rust/src/net/ +
rust/src/fleet/shard.rs).

The build container ships no rust toolchain (see CHANGES.md), so — like
tools/fleet_mirror.py for the in-process fleet — this script re-creates
the NETWORK layer in stdlib Python and measures what BENCH_shard.json
reports: loopback frames/sec, submit round-trip p50/p99, live-migration
wall time, and the tenants_lost == 0 / bit-parity drill.

What is mirrored EXACTLY (any drift here breaks interop with the rust
side, pinned by --selftest against rust/src/net/frame.rs's unit values):

  * the TCFL handshake (4-byte magic + u32 LE version, echoed back);
  * the [len u32][payload] frame layout with the 256 MiB cap;
  * the request/reply payload codec — every op/code byte and field, in
    the table order of rust/src/net/frame.rs;
  * the SplitMix64 tenant->shard placement of rust/src/fleet/shard.rs,
    checked against the same pinned values as its unit tests.

What is a TOY: the tenant behind each shard. Real tenants run the
MicroNet head-training path; here a tenant is a 4-word rolling-hash
state plus a replay arena of --arena-kb bytes, updated deterministically
per event. That keeps the measurement about the PROTOCOL (framing,
routing, drain->restore transfer), not about numpy throughput — and it
preserves the invariant the real system pins: training is a pure
function of (state, event stream), so a tenant drained off shard A and
restored onto shard B must land on bit-identical state and "accuracy"
to one that never moved. The script runs a same-seed 1-shard control
and asserts the determinism block matches byte-for-byte, exactly what
`bench_check.py diff` does to the rust artifacts in CI.

events/sec here UNDERSTATES the rust implementation (Python sockets,
GIL); `cargo run --release -- shard` / `-- shard-client` regenerate the
authoritative numbers wherever a rust toolchain exists.

Usage: python3 tools/shard_mirror.py [--shards 2] [--tenants 8]
           [--events 64] [--arena-kb 128] [--out BENCH_shard.json]
       python3 tools/shard_mirror.py --selftest
"""

import argparse
import json
import socket
import struct
import sys
import threading
import time

MAGIC = b"TCFL"
VERSION = 1
MAX_FRAME = 256 << 20

OP_ADMIT, OP_SUBMIT, OP_INFER, OP_EVAL = 1, 2, 3, 4
OP_DRAIN, OP_RESTORE, OP_STATS, OP_SHUTDOWN = 5, 6, 7, 8
CODE_OK, CODE_ADMITTED, CODE_QUEUED, CODE_REJECTED = 0, 1, 2, 3
CODE_LOGITS, CODE_ACCURACY, CODE_SNAPSHOT, CODE_STATS = 4, 5, 6, 7
CODE_UNKNOWN_TENANT, CODE_ADMISSION, CODE_PROTOCOL = 8, 9, 10
CODE_IO, CODE_INTERNAL, CODE_CONFIG = 11, 12, 13

M64 = (1 << 64) - 1


# ---- rust/src/fleet/shard.rs: shard_of ------------------------------------

def shard_of(tenant, shards):
    """SplitMix64 finalizer mod shards — byte-identical to the rust side."""
    z = (tenant + 0x9E37_79B9_7F4A_7C15) & M64
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & M64
    z ^= z >> 31
    return z % shards


# ---- rust/src/net/frame.rs: framing + codec --------------------------------

def send_frame(sock, payload):
    assert len(payload) <= MAX_FRAME
    sock.sendall(struct.pack("<I", len(payload)) + payload)


def recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def recv_frame(sock):
    head = recv_exact(sock, 4)
    if head is None:
        return None
    (n,) = struct.unpack("<I", head)
    if n > MAX_FRAME:
        raise ValueError(f"frame of {n} bytes exceeds MAX_FRAME_BYTES")
    return recv_exact(sock, n) if n else b""


def client_handshake(sock):
    hello = MAGIC + struct.pack("<I", VERSION)
    sock.sendall(hello)
    echo = recv_exact(sock, 8)
    if echo != hello:
        raise ValueError(f"bad handshake echo {echo!r}")


def server_handshake(sock):
    hello = recv_exact(sock, 8)
    if hello is None or hello[:4] != MAGIC:
        raise ValueError(f"bad magic {hello!r}")
    (version,) = struct.unpack("<I", hello[4:])
    if version != VERSION:
        raise ValueError(f"unsupported protocol version {version}")
    sock.sendall(hello)


def enc_admit(tenant, n_lr, lr_bits, lr, epochs, seed):
    return struct.pack("<BQQBfQQ", OP_ADMIT, tenant, n_lr, lr_bits, lr,
                       epochs, seed)


def enc_submit(tenant, labels, images):
    out = struct.pack("<BQI", OP_SUBMIT, tenant, len(labels))
    out += struct.pack(f"<{len(labels)}i", *labels)
    out += struct.pack("<Q", len(images))
    out += struct.pack(f"<{len(images)}f", *images)
    return out


def enc_eval(tenant):
    return struct.pack("<BQ", OP_EVAL, tenant)


def enc_drain(tenant):
    return struct.pack("<BQ", OP_DRAIN, tenant)


def enc_restore(tenant, snapshot):
    return struct.pack("<BQQ", OP_RESTORE, tenant, len(snapshot)) + snapshot


def enc_stats():
    return struct.pack("<B", OP_STATS)


def enc_shutdown():
    return struct.pack("<B", OP_SHUTDOWN)


def dec_reply(payload):
    """Decode a reply into (code, value). Mirrors decode_reply's shapes
    for the codes this mirror exercises."""
    code = payload[0]
    body = payload[1:]
    if code in (CODE_OK, CODE_QUEUED):
        return code, None
    if code in (CODE_ADMITTED, CODE_REJECTED, CODE_UNKNOWN_TENANT):
        return code, struct.unpack("<Q", body)[0]
    if code == CODE_ACCURACY:
        return code, struct.unpack("<d", body)[0]
    if code == CODE_SNAPSHOT:
        (n,) = struct.unpack("<Q", body[:8])
        assert len(body) == 8 + n, "snapshot frame has trailing bytes"
        return code, body[8:]
    if code == CODE_STATS:
        shard, res, spl, used, budget, sheds, done, n = struct.unpack(
            "<IQQQQQQI", body[:56])
        tenants = []
        off = 56
        for _ in range(n):
            t, last, resident = struct.unpack("<QQB", body[off:off + 17])
            tenants.append((t, last, bool(resident)))
            off += 17
        assert off == len(body), "stats frame has trailing bytes"
        return code, dict(shard=shard, resident=res, spilled=spl,
                          bytes_in_use=used, budget_bytes=budget,
                          sheds=sheds, events_done=done, tenants=tenants)
    if code in (CODE_ADMISSION, CODE_PROTOCOL, CODE_IO, CODE_INTERNAL,
                CODE_CONFIG):
        (n,) = struct.unpack("<I", body[:4])
        return code, body[4:4 + n].decode("utf-8")
    raise ValueError(f"unknown reply code {code}")


# ---- the toy tenant --------------------------------------------------------

def fnv1a64(data, h=0xCBF29CE484222325):
    for b in data:
        h = ((h ^ b) * 0x00000100000001B3) & M64
    return h


class ToyTenant:
    """Deterministic stand-in for a MicroNet head: 4-word rolling state
    plus a replay arena. `train` is a pure function of (state, event) —
    the property that makes migration bit-invisible."""

    def __init__(self, seed, arena_bytes):
        self.state = [fnv1a64(struct.pack("<QQ", seed, i)) for i in range(4)]
        self.arena = bytearray(
            fnv1a64(struct.pack("<QQ", seed, i)) & 0xFF
            for i in range(arena_bytes)
        )
        self.events = 0

    def train(self, labels, images_bytes):
        mix = fnv1a64(images_bytes, fnv1a64(struct.pack(
            f"<{len(labels)}i", *labels)))
        for i in range(4):
            self.state[i] = fnv1a64(struct.pack("<QQ", self.state[i], mix))
        # touch a deterministic arena slice (replay insert stand-in)
        off = mix % max(1, len(self.arena) - 64)
        for i in range(min(64, len(self.arena))):
            self.arena[off + i] = (self.arena[off + i] ^ (mix >> (i % 8))) & 0xFF
        self.events += 1

    def accuracy(self):
        h = fnv1a64(bytes(self.arena), self.state[0])
        return (h % 10**9) / 10**9

    def snapshot(self):
        return struct.pack("<QQQQQQ", *self.state, self.events,
                           len(self.arena)) + bytes(self.arena)

    @classmethod
    def restore(cls, blob):
        t = cls.__new__(cls)
        vals = struct.unpack("<QQQQQQ", blob[:48])
        t.state = list(vals[:4])
        t.events = vals[4]
        n = vals[5]
        assert len(blob) == 48 + n, "toy snapshot has trailing bytes"
        t.arena = bytearray(blob[48:])
        return t


# ---- the toy shard server --------------------------------------------------

class ToyShard(threading.Thread):
    def __init__(self, index, arena_bytes):
        super().__init__(daemon=True)
        self.index = index
        self.arena_bytes = arena_bytes
        self.tenants = {}
        self.lock = threading.Lock()
        self.events_done = 0
        self.listener = socket.create_server(("127.0.0.1", 0))
        self.addr = self.listener.getsockname()
        self.stop = False

    def run(self):
        while not self.stop:
            try:
                conn, _ = self.listener.accept()
            except OSError:
                return
            threading.Thread(target=self.handle, args=(conn,),
                             daemon=True).start()

    def handle(self, conn):
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            server_handshake(conn)
            while True:
                payload = recv_frame(conn)
                if payload is None:
                    return
                send_frame(conn, self.dispatch(payload))
        except (ValueError, OSError):
            return
        finally:
            conn.close()

    def dispatch(self, payload):
        op = payload[0]
        body = payload[1:]
        with self.lock:
            if op == OP_ADMIT:
                tenant, n_lr, lr_bits, lr, epochs, seed = struct.unpack(
                    "<QQBfQQ", body)
                if tenant in self.tenants:
                    msg = f"tenant {tenant} already admitted".encode()
                    return struct.pack("<BI", CODE_ADMISSION, len(msg)) + msg
                self.tenants[tenant] = ToyTenant(seed, self.arena_bytes)
                return struct.pack("<BQ", CODE_ADMITTED, tenant)
            if op == OP_SUBMIT:
                tenant, rows = struct.unpack("<QI", body[:12])
                if tenant not in self.tenants:
                    return struct.pack("<BQ", CODE_UNKNOWN_TENANT, tenant)
                labels = struct.unpack(f"<{rows}i", body[12:12 + 4 * rows])
                images_bytes = body[12 + 4 * rows + 8:]
                self.tenants[tenant].train(labels, images_bytes)
                self.events_done += 1
                return struct.pack("<B", CODE_QUEUED)
            if op == OP_EVAL:
                (tenant,) = struct.unpack("<Q", body)
                if tenant not in self.tenants:
                    return struct.pack("<BQ", CODE_UNKNOWN_TENANT, tenant)
                return struct.pack("<Bd", CODE_ACCURACY,
                                   self.tenants[tenant].accuracy())
            if op == OP_DRAIN:
                (tenant,) = struct.unpack("<Q", body)
                if tenant not in self.tenants:
                    return struct.pack("<BQ", CODE_UNKNOWN_TENANT, tenant)
                blob = self.tenants.pop(tenant).snapshot()
                return struct.pack("<BQ", CODE_SNAPSHOT, len(blob)) + blob
            if op == OP_RESTORE:
                tenant, n = struct.unpack("<QQ", body[:16])
                if tenant in self.tenants:
                    msg = f"tenant {tenant} already resident".encode()
                    return struct.pack("<BI", CODE_ADMISSION, len(msg)) + msg
                self.tenants[tenant] = ToyTenant.restore(body[16:16 + n])
                return struct.pack("<B", CODE_OK)
            if op == OP_STATS:
                out = struct.pack("<BIQQQQQQI", CODE_STATS, self.index,
                                  len(self.tenants), 0,
                                  sum(len(t.arena) for t in
                                      self.tenants.values()),
                                  64 << 20, 0, self.events_done,
                                  len(self.tenants))
                for gid, t in sorted(self.tenants.items()):
                    out += struct.pack("<QQB", gid, t.events, 1)
                return out
            if op == OP_SHUTDOWN:
                self.stop = True
                self.listener.close()
                return struct.pack("<B", CODE_OK)
        raise ValueError(f"unknown request op {op}")


# ---- the client + measurement ----------------------------------------------

class Client:
    def __init__(self, addrs):
        self.socks = []
        for host, port in addrs:
            s = socket.create_connection((host, port))
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            client_handshake(s)
            self.socks.append(s)
        self.pins = {}

    def route(self, tenant):
        return self.pins.get(tenant, shard_of(tenant, len(self.socks)))

    def call(self, shard, payload):
        send_frame(self.socks[shard], payload)
        reply = recv_frame(self.socks[shard])
        if reply is None:
            raise ValueError(f"shard {shard} hung up")
        return dec_reply(reply)

    def call_routed(self, tenant, payload):
        return self.call(self.route(tenant), payload)

    def migrate(self, tenant, to):
        src = self.route(tenant)
        code, blob = self.call(src, enc_drain(tenant))
        assert code == CODE_SNAPSHOT, f"drain failed: {code}"
        code, _ = self.call(to, enc_restore(tenant, blob))
        assert code == CODE_OK, f"restore failed: {code}"
        self.pins[tenant] = to
        return len(blob)

    def close(self):
        for s in self.socks:
            s.close()


def event_payload(tenant, seed, k, rows=8, feat=48):
    """A deterministic toy event: `rows` labels + a small image block.
    Same (tenant, seed, k) -> same bytes, on any client."""
    labels = [(seed + tenant * 31 + k * 7 + i) % 10 for i in range(rows)]
    imgs = [((seed * 131 + tenant * 17 + k * 13 + i) % 256) / 255.0
            for i in range(rows * feat)]
    return enc_submit(tenant, labels, imgs)


def acc_bits(value):
    return f"{struct.unpack('<Q', struct.pack('<d', value))[0]:016x}"


def run_fleet(n_shards, n_tenants, events_per_tenant, arena_kb, seed,
              migrate_at=None):
    """Serve the full drill against n_shards toy shards; returns the
    BENCH record. With migrate_at=(leg1_events), tenant 0 live-migrates
    off its home shard between the two legs."""
    shards = [ToyShard(i, arena_kb * 1024) for i in range(n_shards)]
    for s in shards:
        s.start()
    client = Client([s.addr for s in shards])
    try:
        for g in range(n_tenants):
            code, _ = client.call_routed(
                g, enc_admit(g, 4096, 8, 0.1, 2, seed + g))
            assert code == CODE_ADMITTED
        rtts = []
        migrations = 0
        snapshot_bytes = 0
        migrate_ms = 0.0
        t0 = time.perf_counter()
        leg1 = migrate_at if migrate_at is not None else events_per_tenant
        for k in range(leg1):
            for g in range(n_tenants):
                t1 = time.perf_counter()
                code, _ = client.call_routed(g, event_payload(g, seed, k))
                rtts.append(time.perf_counter() - t1)
                assert code == CODE_QUEUED
        if migrate_at is not None and n_shards > 1:
            home = client.route(0)
            tm = time.perf_counter()
            snapshot_bytes = client.migrate(0, (home + 1) % n_shards)
            migrate_ms = (time.perf_counter() - tm) * 1e3
            migrations = 1
        for k in range(leg1, events_per_tenant):
            for g in range(n_tenants):
                t1 = time.perf_counter()
                code, _ = client.call_routed(g, event_payload(g, seed, k))
                rtts.append(time.perf_counter() - t1)
                assert code == CODE_QUEUED
        wall = time.perf_counter() - t0
        accs, lost = {}, 0
        for g in range(n_tenants):
            code, val = client.call_routed(g, enc_eval(g))
            if code != CODE_ACCURACY:
                lost += 1
                continue
            accs[str(g)] = acc_bits(val)
        code, stats0 = client.call(0, enc_stats())
        assert code == CODE_STATS
        for i in range(n_shards):
            client.call(i, enc_shutdown())
    finally:
        client.close()
    total = n_tenants * events_per_tenant
    rtts.sort()

    def pct(q):
        return rtts[min(len(rtts) - 1, int(q * len(rtts)))] * 1e3

    return {
        "bench": "shard",
        "shards": n_shards,
        "tenants": n_tenants,
        "events_per_tenant": events_per_tenant,
        "events": total,
        "events_per_sec": round(total / wall, 1),
        "submit_rtt_p50_ms": round(pct(0.50), 4),
        "submit_rtt_p99_ms": round(pct(0.99), 4),
        "sheds": 0,
        "migrations": migrations,
        "migration_ms": round(migrate_ms, 3),
        "snapshot_bytes": snapshot_bytes,
        "tenants_lost": lost,
        "stats_probe": {"shard": stats0["shard"],
                        "events_done": stats0["events_done"]},
        "determinism": {"acc_bits": accs},
    }


# ---- selftest: pinned interop values ---------------------------------------

def selftest():
    # shard_of against the values pinned in rust/src/fleet/shard.rs tests
    assert [shard_of(t, 2) for t in range(8)] == [1, 1, 0, 1, 0, 0, 0, 1]
    assert [shard_of(t, 3) for t in range(8)] == [1, 2, 1, 0, 1, 2, 2, 0]
    assert shard_of(42, 4) == 1
    assert shard_of(1000, 4) == 0 and shard_of(1001, 4) == 0
    # frame layout: admit body is op + 8+8+1+4+8+8 = 38 bytes
    assert len(enc_admit(7, 4096, 8, 0.1, 2, 42)) == 38
    # submit: op + tenant + rows + labels + imglen + f32s
    p = enc_submit(3, [1, 2], [0.5, 0.25, 0.125])
    assert len(p) == 1 + 8 + 4 + 8 + 8 + 12
    assert p[0] == OP_SUBMIT
    # reply round-trips
    assert dec_reply(struct.pack("<Bd", CODE_ACCURACY, 0.625)) == (
        CODE_ACCURACY, 0.625)
    code, blob = dec_reply(struct.pack("<BQ", CODE_SNAPSHOT, 3) + b"abc")
    assert (code, blob) == (CODE_SNAPSHOT, b"abc")
    # toy tenant: snapshot round-trip is bit-exact and training is pure
    a = ToyTenant(42, 1024)
    a.train([1, 2, 3], b"imgs")
    b = ToyTenant.restore(a.snapshot())
    assert b.snapshot() == a.snapshot()
    a.train([4], b"more")
    b.train([4], b"more")
    assert a.accuracy() == b.accuracy()
    print("shard_mirror: selftest OK")


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--events", type=int, default=64)
    ap.add_argument("--arena-kb", type=int, default=128)
    ap.add_argument("--seed", type=int, default=1000)
    ap.add_argument("--out", default=None)
    ap.add_argument("--selftest", action="store_true")
    args = ap.parse_args()
    if args.selftest:
        selftest()
        return
    selftest()

    sharded = run_fleet(args.shards, args.tenants, args.events,
                        args.arena_kb, args.seed,
                        migrate_at=args.events // 2)
    control = run_fleet(1, args.tenants, args.events, args.arena_kb,
                        args.seed)
    if sharded["determinism"] != control["determinism"]:
        print("shard_mirror: FAIL: sharded run's accuracy bits diverge "
              "from the 1-shard control", file=sys.stderr)
        sys.exit(1)
    print(f"shard_mirror: {args.shards} shards x {args.tenants} tenants x "
          f"{args.events} events: {sharded['events_per_sec']} events/s, "
          f"submit RTT p50 {sharded['submit_rtt_p50_ms']} ms "
          f"p99 {sharded['submit_rtt_p99_ms']} ms")
    print(f"shard_mirror: migration: {sharded['snapshot_bytes']} snapshot "
          f"bytes in {sharded['migration_ms']} ms, "
          f"{sharded['tenants_lost']} tenants lost")
    print("shard_mirror: determinism.acc_bits identical to the 1-shard "
          f"control ({len(control['determinism']['acc_bits'])} tenants)")
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(sharded, f, indent=2)
            f.write("\n")
        print(f"shard_mirror: wrote {args.out}")


if __name__ == "__main__":
    main()
