#!/usr/bin/env python3
"""Measurement mirror of the sharded serving layer (rust/src/net/ +
rust/src/fleet/shard.rs, .../faults.rs, .../supervisor.rs).

The build container ships no rust toolchain (see CHANGES.md), so — like
tools/fleet_mirror.py for the in-process fleet — this script re-creates
the NETWORK layer in stdlib Python and measures what BENCH_shard.json
reports: loopback frames/sec, submit round-trip p50/p99, live-migration
wall time, and the partition-tolerance drill (seeded network chaos,
exactly-once duplicates, crash-mid-migration rollback + restart MTTR).

What is mirrored EXACTLY (any drift here breaks interop with the rust
side, pinned by --selftest against rust/src/net/frame.rs's unit values):

  * the TCFL handshake (4-byte magic + u32 LE version 2, echoed back);
  * the [len u32][payload] frame layout with the 256 MiB cap;
  * the request/reply payload codec, including the (client_id, seq)
    idempotency stamp on Admit/Submit/Restore, the Ping /
    MigrateCommit / MigrateAbort ops and the Duplicate / ShardDown
    reply codes — every op/code byte and field, in the table order of
    rust/src/net/frame.rs;
  * the SplitMix64 tenant->shard placement of rust/src/fleet/shard.rs;
  * the xoshiro256** decision RNG of rust/src/util/rng.rs and the
    pure-(seed, domain, op, attempt) network fault decisions of
    rust/src/fleet/faults.rs (net_recovering preset) — the injected
    fault stream here is the SAME schedule a rust client would see.

What is a TOY: the tenant behind each shard (a 4-word rolling-hash
state plus a replay arena — training is a pure function of
(state, event stream)), the shard process (a thread), and the
supervisor (restart-in-place with a fresh port). The invariants are the
real ones: a chaos run's accuracy bits must equal the clean 1-shard
control's byte-for-byte, a re-delivered stamp must be acked Duplicate
and applied once, and the crash-mid-migration drill must end with
tenants_lost == 0.

events/sec here UNDERSTATES the rust implementation (Python sockets,
GIL); `cargo run --release -- shard` / `-- shard-client` /
`-- supervise` regenerate the authoritative numbers wherever a rust
toolchain exists.

Usage: python3 tools/shard_mirror.py [--shards 2] [--tenants 8]
           [--events 64] [--arena-kb 128] [--fault-seed 11]
           [--out BENCH_shard.json]
       python3 tools/shard_mirror.py --selftest
"""

import argparse
import json
import socket
import struct
import sys
import threading
import time

MAGIC = b"TCFL"
VERSION = 2
MAX_FRAME = 256 << 20

OP_ADMIT, OP_SUBMIT, OP_INFER, OP_EVAL = 1, 2, 3, 4
OP_DRAIN, OP_RESTORE, OP_STATS, OP_SHUTDOWN = 5, 6, 7, 8
OP_PING, OP_MIGRATE_COMMIT, OP_MIGRATE_ABORT = 9, 10, 11
CODE_OK, CODE_ADMITTED, CODE_QUEUED, CODE_REJECTED = 0, 1, 2, 3
CODE_LOGITS, CODE_ACCURACY, CODE_SNAPSHOT, CODE_STATS = 4, 5, 6, 7
CODE_UNKNOWN_TENANT, CODE_ADMISSION, CODE_PROTOCOL = 8, 9, 10
CODE_IO, CODE_INTERNAL, CODE_CONFIG = 11, 12, 13
CODE_DUPLICATE, CODE_SHARD_DOWN = 14, 15

M64 = (1 << 64) - 1


# ---- rust/src/fleet/shard.rs: shard_of ------------------------------------

def shard_of(tenant, shards):
    """SplitMix64 finalizer mod shards — byte-identical to the rust side."""
    z = (tenant + 0x9E37_79B9_7F4A_7C15) & M64
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & M64
    z ^= z >> 31
    return z % shards


# ---- rust/src/util/rng.rs: xoshiro256** -----------------------------------

def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Rng:
    """xoshiro256** seeded via splitmix64 — bit-identical port of
    rust/src/util/rng.rs (the generator behind every fault decision)."""

    def __init__(self, seed):
        s, sm = [], seed & M64
        for _ in range(4):
            sm = (sm + 0x9E37_79B9_7F4A_7C15) & M64
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & M64
            z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & M64
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & M64, 7) * 9) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def below(self, n):
        assert n > 0
        zone = M64 - (M64 % n) if M64 % n != n - 1 else M64
        # exact mirror of the rust rejection loop: zone = MAX - MAX % n
        zone = M64 - (M64 % n)
        while True:
            v = self.next_u64()
            if v < zone:
                return v % n

    def range_f64(self, lo, hi):
        return lo + self.f64() * (hi - lo)


# ---- rust/src/fleet/faults.rs: network fault decisions ---------------------

DOMAIN_CONNECT = 0x43_4F_4E_4E        # "CONN"
DOMAIN_FRAME_WRITE = 0x46_57_52_49_54  # "FWRIT"
DOMAIN_FRAME_READ = 0x46_52_45_41_44   # "FREAD"
DOMAIN_NET_STALL = 0x4E_53_54_41_4C    # "NSTAL"
GOLDEN = 0x9E37_79B9_7F4A_7C15


def decision_rng(seed, domain, op):
    return Rng(seed ^ ((domain * GOLDEN) & M64)
               ^ ((op * 0xD1B5_4A32_D192_ED03) & M64))


class FaultPlan:
    """The net_recovering preset of rust/src/fleet/faults.rs: every
    decision is pure in (seed, domain, op, attempt), so the schedule a
    Python client draws is the one a rust client at the same logical op
    indices would draw."""

    def __init__(self, seed, connect_p=0.30, connect_streak=2,
                 frame_p=0.35, frame_streak=2, torn=True,
                 stall_p=0.08, stall_s=0.0002):
        self.seed = seed
        self.connect_p = connect_p
        self.connect_streak = max(1, connect_streak)
        self.frame_p = frame_p
        self.frame_streak = max(1, frame_streak)
        self.torn = torn
        self.stall_p = stall_p
        self.stall_s = stall_s

    def connect_fault(self, op, attempt):
        rng = decision_rng(self.seed, DOMAIN_CONNECT, op)
        hit = rng.f64() < self.connect_p
        streak = 1 + rng.below(self.connect_streak)
        if not hit or attempt >= streak:
            return None
        return ("drop",)

    def frame_write_fault(self, op, attempt):
        rng = decision_rng(self.seed, DOMAIN_FRAME_WRITE, op)
        hit = rng.f64() < self.frame_p
        streak = 1 + rng.below(self.frame_streak)
        kind = rng.f64()
        frac = rng.range_f64(0.05, 0.95)
        if not hit or attempt >= streak:
            return None
        if self.torn and kind < 0.45:
            return ("torn", frac)
        return ("drop",)

    def frame_read_fault(self, op, attempt):
        rng = decision_rng(self.seed, DOMAIN_FRAME_READ, op)
        hit = rng.f64() < self.frame_p
        streak = 1 + rng.below(self.frame_streak)
        if not hit or attempt >= streak:
            return None
        return ("drop",)

    def net_stall(self, op):
        rng = decision_rng(self.seed, DOMAIN_NET_STALL, op)
        return self.stall_s if rng.f64() < self.stall_p else None


# ---- rust/src/net/frame.rs: framing + codec --------------------------------

def send_frame(sock, payload):
    assert len(payload) <= MAX_FRAME
    sock.sendall(struct.pack("<I", len(payload)) + payload)


def recv_exact(sock, n):
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def recv_frame(sock):
    head = recv_exact(sock, 4)
    if head is None:
        return None
    (n,) = struct.unpack("<I", head)
    if n > MAX_FRAME:
        raise ValueError(f"frame of {n} bytes exceeds MAX_FRAME_BYTES")
    return recv_exact(sock, n) if n else b""


def client_handshake(sock):
    hello = MAGIC + struct.pack("<I", VERSION)
    sock.sendall(hello)
    echo = recv_exact(sock, 8)
    if echo != hello:
        raise ValueError(f"bad handshake echo {echo!r}")


def server_handshake(sock):
    hello = recv_exact(sock, 8)
    if hello is None or hello[:4] != MAGIC:
        raise ValueError(f"bad magic {hello!r}")
    (version,) = struct.unpack("<I", hello[4:])
    if version != VERSION:
        raise ValueError(f"unsupported protocol version {version}")
    sock.sendall(hello)


# stamped mutations carry (client_id, seq) right after the tenant id;
# (0, 0) is the unstamped escape hatch (exactly the rust layout)

def enc_admit(tenant, cid, seq, n_lr, lr_bits, lr, epochs, seed):
    return struct.pack("<BQQQQBfQQ", OP_ADMIT, tenant, cid, seq,
                       n_lr, lr_bits, lr, epochs, seed)


def enc_submit(tenant, cid, seq, labels, images):
    out = struct.pack("<BQQQI", OP_SUBMIT, tenant, cid, seq, len(labels))
    out += struct.pack(f"<{len(labels)}i", *labels)
    out += struct.pack("<Q", len(images))
    out += struct.pack(f"<{len(images)}f", *images)
    return out


def enc_eval(tenant):
    return struct.pack("<BQ", OP_EVAL, tenant)


def enc_drain(tenant):
    return struct.pack("<BQ", OP_DRAIN, tenant)


def enc_restore(tenant, cid, seq, snapshot):
    return struct.pack("<BQQQQ", OP_RESTORE, tenant, cid, seq,
                       len(snapshot)) + snapshot


def enc_stats():
    return struct.pack("<B", OP_STATS)


def enc_shutdown():
    return struct.pack("<B", OP_SHUTDOWN)


def enc_ping():
    return struct.pack("<B", OP_PING)


def enc_migrate_commit(tenant):
    return struct.pack("<BQ", OP_MIGRATE_COMMIT, tenant)


def enc_migrate_abort(tenant):
    return struct.pack("<BQ", OP_MIGRATE_ABORT, tenant)


def dec_reply(payload):
    """Decode a reply into (code, value). Mirrors decode_reply's shapes
    for the codes this mirror exercises."""
    code = payload[0]
    body = payload[1:]
    if code in (CODE_OK, CODE_QUEUED, CODE_DUPLICATE):
        return code, None
    if code in (CODE_ADMITTED, CODE_REJECTED, CODE_UNKNOWN_TENANT,
                CODE_SHARD_DOWN):
        return code, struct.unpack("<Q", body)[0]
    if code == CODE_ACCURACY:
        return code, struct.unpack("<d", body)[0]
    if code == CODE_SNAPSHOT:
        (n,) = struct.unpack("<Q", body[:8])
        assert len(body) == 8 + n, "snapshot frame has trailing bytes"
        return code, body[8:]
    if code == CODE_STATS:
        shard, res, spl, used, budget, sheds, done, n = struct.unpack(
            "<IQQQQQQI", body[:56])
        tenants = []
        off = 56
        for _ in range(n):
            t, last, resident = struct.unpack("<QQB", body[off:off + 17])
            tenants.append((t, last, bool(resident)))
            off += 17
        assert off == len(body), "stats frame has trailing bytes"
        return code, dict(shard=shard, resident=res, spilled=spl,
                          bytes_in_use=used, budget_bytes=budget,
                          sheds=sheds, events_done=done, tenants=tenants)
    if code in (CODE_ADMISSION, CODE_PROTOCOL, CODE_IO, CODE_INTERNAL,
                CODE_CONFIG):
        (n,) = struct.unpack("<I", body[:4])
        return code, body[4:4 + n].decode("utf-8")
    raise ValueError(f"unknown reply code {code}")


# ---- the toy tenant --------------------------------------------------------

def fnv1a64(data, h=0xCBF29CE484222325):
    for b in data:
        h = ((h ^ b) * 0x00000100000001B3) & M64
    return h


class ToyTenant:
    """Deterministic stand-in for a MicroNet head: 4-word rolling state
    plus a replay arena. `train` is a pure function of (state, event) —
    the property that makes migration bit-invisible."""

    def __init__(self, seed, arena_bytes):
        self.state = [fnv1a64(struct.pack("<QQ", seed, i)) for i in range(4)]
        self.arena = bytearray(
            fnv1a64(struct.pack("<QQ", seed, i)) & 0xFF
            for i in range(arena_bytes)
        )
        self.events = 0

    def train(self, labels, images_bytes):
        mix = fnv1a64(images_bytes, fnv1a64(struct.pack(
            f"<{len(labels)}i", *labels)))
        for i in range(4):
            self.state[i] = fnv1a64(struct.pack("<QQ", self.state[i], mix))
        # touch a deterministic arena slice (replay insert stand-in)
        off = mix % max(1, len(self.arena) - 64)
        for i in range(min(64, len(self.arena))):
            self.arena[off + i] = (self.arena[off + i] ^ (mix >> (i % 8))) & 0xFF
        self.events += 1

    def accuracy(self):
        h = fnv1a64(bytes(self.arena), self.state[0])
        return (h % 10**9) / 10**9

    def snapshot(self):
        return struct.pack("<QQQQQQ", *self.state, self.events,
                           len(self.arena)) + bytes(self.arena)

    @classmethod
    def restore(cls, blob):
        t = cls.__new__(cls)
        vals = struct.unpack("<QQQQQQ", blob[:48])
        t.state = list(vals[:4])
        t.events = vals[4]
        n = vals[5]
        assert len(blob) == 48 + n, "toy snapshot has trailing bytes"
        t.arena = bytearray(blob[48:])
        return t


# ---- the toy shard server --------------------------------------------------

class ToyShard(threading.Thread):
    """One shard: accept loop, dedup window, tombstoned two-phase
    migration, and an optional scripted crash (the process "exits" —
    listener closed, state dropped — after serving N frames, with the
    dying frame applied but never acknowledged, exactly the rust crash
    hook's worst-case ordering)."""

    def __init__(self, index, arena_bytes, crash_after_frames=None):
        super().__init__(daemon=True)
        self.index = index
        self.arena_bytes = arena_bytes
        self.tenants = {}
        self.settled = {}  # (client_id, tenant) -> set of applied seqs
        self.tombs = {}    # tenant -> snapshot bytes awaiting commit/abort
        self.lock = threading.Lock()
        self.events_done = 0
        self.frames_served = 0
        self.crash_after_frames = crash_after_frames
        self.crashed = False
        self.listener = socket.create_server(("127.0.0.1", 0))
        self.addr = self.listener.getsockname()
        self.stop = False

    def run(self):
        while not self.stop:
            try:
                conn, _ = self.listener.accept()
            except OSError:
                return
            threading.Thread(target=self.handle, args=(conn,),
                             daemon=True).start()

    def close_listener(self):
        # shutdown() first: close() alone leaves the kernel socket
        # accepting while run() is blocked in accept() (the in-flight
        # syscall keeps it alive), so the port would NOT refuse
        try:
            self.listener.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.listener.close()

    def die(self, conn):
        """The scripted crash: drop everything, reply to no one."""
        self.crashed = True
        self.stop = True
        with self.lock:
            self.tenants.clear()
            self.settled.clear()
            self.tombs.clear()
        try:
            self.close_listener()
        finally:
            conn.close()

    def handle(self, conn):
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            server_handshake(conn)
            while True:
                payload = recv_frame(conn)
                if payload is None:
                    return
                reply = self.dispatch(payload)
                # crash AFTER the apply, BEFORE the reply — the most
                # ambiguous point a client can face
                self.frames_served += 1
                if (self.crash_after_frames is not None and not self.crashed
                        and self.frames_served >= self.crash_after_frames):
                    self.die(conn)
                    return
                send_frame(conn, reply)
        except (ValueError, OSError):
            return
        finally:
            conn.close()

    def dedup_hit(self, cid, tenant, seq):
        if cid == 0:
            return False
        return seq in self.settled.setdefault((cid, tenant), set())

    def settle(self, cid, tenant, seq):
        if cid:
            self.settled[(cid, tenant)].add(seq)

    def dispatch(self, payload):
        op = payload[0]
        body = payload[1:]
        with self.lock:
            if op == OP_ADMIT:
                tenant, cid, seq, n_lr, lr_bits, lr, epochs, seed = \
                    struct.unpack("<QQQQBfQQ", body)
                if self.dedup_hit(cid, tenant, seq):
                    return struct.pack("<B", CODE_DUPLICATE)
                if tenant in self.tenants:
                    msg = f"tenant {tenant} already admitted".encode()
                    return struct.pack("<BI", CODE_ADMISSION, len(msg)) + msg
                self.tenants[tenant] = ToyTenant(seed, self.arena_bytes)
                self.settle(cid, tenant, seq)
                return struct.pack("<BQ", CODE_ADMITTED, tenant)
            if op == OP_SUBMIT:
                tenant, cid, seq, rows = struct.unpack("<QQQI", body[:28])
                if self.dedup_hit(cid, tenant, seq):
                    return struct.pack("<B", CODE_DUPLICATE)
                if tenant not in self.tenants:
                    return struct.pack("<BQ", CODE_UNKNOWN_TENANT, tenant)
                labels = struct.unpack(f"<{rows}i", body[28:28 + 4 * rows])
                images_bytes = body[28 + 4 * rows + 8:]
                self.tenants[tenant].train(labels, images_bytes)
                self.events_done += 1
                self.settle(cid, tenant, seq)
                return struct.pack("<B", CODE_QUEUED)
            if op == OP_EVAL:
                (tenant,) = struct.unpack("<Q", body)
                if tenant not in self.tenants:
                    return struct.pack("<BQ", CODE_UNKNOWN_TENANT, tenant)
                return struct.pack("<Bd", CODE_ACCURACY,
                                   self.tenants[tenant].accuracy())
            if op == OP_DRAIN:
                (tenant,) = struct.unpack("<Q", body)
                if tenant in self.tombs:
                    # idempotent: a retried Drain re-reads the tombstone
                    blob = self.tombs[tenant]
                    return struct.pack("<BQ", CODE_SNAPSHOT, len(blob)) + blob
                if tenant not in self.tenants:
                    return struct.pack("<BQ", CODE_UNKNOWN_TENANT, tenant)
                blob = self.tenants.pop(tenant).snapshot()
                self.tombs[tenant] = blob
                return struct.pack("<BQ", CODE_SNAPSHOT, len(blob)) + blob
            if op == OP_RESTORE:
                tenant, cid, seq, n = struct.unpack("<QQQQ", body[:32])
                if self.dedup_hit(cid, tenant, seq):
                    return struct.pack("<B", CODE_DUPLICATE)
                if tenant in self.tenants:
                    msg = f"tenant {tenant} already resident".encode()
                    return struct.pack("<BI", CODE_ADMISSION, len(msg)) + msg
                self.tenants[tenant] = ToyTenant.restore(body[32:32 + n])
                self.settle(cid, tenant, seq)
                return struct.pack("<B", CODE_OK)
            if op == OP_MIGRATE_COMMIT:
                (tenant,) = struct.unpack("<Q", body)
                self.tombs.pop(tenant, None)
                return struct.pack("<B", CODE_OK)
            if op == OP_MIGRATE_ABORT:
                (tenant,) = struct.unpack("<Q", body)
                if tenant in self.tenants:
                    return struct.pack("<B", CODE_OK)
                if tenant not in self.tombs:
                    return struct.pack("<BQ", CODE_UNKNOWN_TENANT, tenant)
                self.tenants[tenant] = ToyTenant.restore(
                    self.tombs.pop(tenant))
                return struct.pack("<B", CODE_OK)
            if op == OP_PING:
                return struct.pack("<B", CODE_OK)
            if op == OP_STATS:
                out = struct.pack("<BIQQQQQQI", CODE_STATS, self.index,
                                  len(self.tenants), 0,
                                  sum(len(t.arena) for t in
                                      self.tenants.values()),
                                  64 << 20, 0, self.events_done,
                                  len(self.tenants))
                for gid, t in sorted(self.tenants.items()):
                    out += struct.pack("<QQB", gid, t.events, 1)
                return out
            if op == OP_SHUTDOWN:
                self.stop = True
                self.close_listener()
                return struct.pack("<B", CODE_OK)
        raise ValueError(f"unknown request op {op}")


# ---- the client: stamps, fault injection, retries, failover ----------------

RETRY_ATTEMPTS = 4
RETRY_BASE_S = 0.001


class Client:
    """Mirror of RemoteClient + FleetClient: per-tenant stamp minting,
    per-client logical op counters feeding the fault schedule,
    reconnect-before-retry, duplicate accounting, pin-map routing and
    two-phase migration with rollback."""

    def __init__(self, addrs, plan=None, client_id=0):
        self.plan = plan
        self.client_id = client_id
        self.addrs = list(addrs)
        self.seqs = {}
        self.connect_ops = 0
        self.frame_ops = 0
        self.net_retries = 0
        self.duplicates = 0
        self.socks = [self.dial(a) for a in addrs]
        self.pins = {}

    def dial(self, addr):
        op = self.connect_ops
        self.connect_ops += 1
        last = None
        for attempt in range(RETRY_ATTEMPTS):
            if attempt:
                self.net_retries += 1
                time.sleep(RETRY_BASE_S * (1 << (attempt - 1)))
            fault = self.plan.connect_fault(op, attempt) if self.plan else None
            if fault:
                last = OSError("ECONNREFUSED: injected connect failure")
                continue
            try:
                s = socket.create_connection(addr, timeout=10)
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                client_handshake(s)
                return s
            except OSError as e:
                last = e
        raise last

    def next_stamp(self, tenant):
        if self.client_id == 0:
            return 0, 0
        seq = self.seqs.get(tenant, 0) + 1
        self.seqs[tenant] = seq
        return self.client_id, seq

    def route(self, tenant):
        return self.pins.get(tenant, shard_of(tenant, len(self.socks)))

    def attempt(self, shard, payload, op, attempt):
        sock = self.socks[shard]
        if self.plan:
            stall = self.plan.net_stall(op)
            if stall:
                time.sleep(stall)
            fault = self.plan.frame_write_fault(op, attempt)
            if fault and fault[0] == "torn":
                # the injected lie: a truncated frame that "succeeds" —
                # the peer sees mid-frame EOF, we see a lost reply
                head = struct.pack("<I", len(payload))
                sock.sendall(head + payload[:int(len(payload) * fault[1])])
                sock.close()
            elif fault:
                sock.close()
                raise OSError("ECONNRESET: injected send failure")
            else:
                send_frame(sock, payload)
            rfault = self.plan.frame_read_fault(op, attempt)
            if rfault:
                sock.close()
                raise OSError("ECONNRESET: injected receive failure")
        else:
            send_frame(sock, payload)
        reply = recv_frame(sock)
        if reply is None:
            raise OSError("connection closed while waiting for a reply")
        return reply

    def call(self, shard, payload, retryable=True):
        """One logical request: one frame-op index, up to RETRY_ATTEMPTS
        tries, reconnecting before every retry (rust call() exactly).
        Only stamped/idempotent requests may pass retryable=True."""
        op = self.frame_ops
        self.frame_ops += 1
        attempts = RETRY_ATTEMPTS if retryable else 1
        last = None
        for attempt in range(attempts):
            if attempt:
                self.net_retries += 1
                time.sleep(RETRY_BASE_S * (1 << (attempt - 1)))
                try:
                    self.socks[shard] = self.dial(self.addrs[shard])
                except OSError as e:
                    last = e
                    continue
            try:
                reply = self.attempt(shard, payload, op, attempt)
            except (OSError, ValueError) as e:
                last = e
                continue
            code, val = dec_reply(reply)
            if code == CODE_DUPLICATE:
                self.duplicates += 1
            return code, val
        raise last

    def call_routed(self, tenant, payload):
        return self.call(self.route(tenant), payload)

    def admit(self, tenant, seed, n_lr=4096):
        cid, seq = self.next_stamp(tenant)
        code, _ = self.call_routed(
            tenant, enc_admit(tenant, cid, seq, n_lr, 8, 0.1, 2, seed))
        assert code in (CODE_ADMITTED, CODE_DUPLICATE), f"admit: {code}"

    def submit(self, tenant, labels, images):
        cid, seq = self.next_stamp(tenant)
        code, _ = self.call_routed(
            tenant, enc_submit(tenant, cid, seq, labels, images))
        assert code in (CODE_QUEUED, CODE_DUPLICATE), f"submit: {code}"

    def migrate(self, tenant, to):
        """Two-phase: Drain leaves a tombstone on the source until the
        destination's Restore is confirmed; any failure rolls back via
        MigrateAbort with the pin restored (rust FleetClient::migrate)."""
        src = self.route(tenant)
        code, blob = self.call(src, enc_drain(tenant))
        assert code == CODE_SNAPSHOT, f"drain failed: {code}"
        cid, seq = self.next_stamp(tenant)
        try:
            code, val = self.call(to, enc_restore(tenant, cid, seq, blob))
        except (OSError, ValueError):
            self.pins[tenant] = src
            c, _ = self.call(src, enc_migrate_abort(tenant))
            assert c == CODE_OK, f"abort failed: {c}"
            raise
        if code in (CODE_OK, CODE_DUPLICATE):
            self.pins[tenant] = to
            c, _ = self.call(src, enc_migrate_commit(tenant))
            assert c == CODE_OK, f"commit failed: {c}"
            return len(blob)
        self.pins[tenant] = src
        c, _ = self.call(src, enc_migrate_abort(tenant))
        assert c == CODE_OK, f"abort failed: {c}"
        raise RuntimeError(f"restore rejected: code {code} ({val})")

    def re_resolve(self, addrs):
        """Adopt a rewritten address list (post-restart) and reconnect."""
        assert len(addrs) == len(self.socks)
        self.addrs = list(addrs)
        for i, addr in enumerate(addrs):
            try:
                self.socks[i].close()
            except OSError:
                pass
            self.socks[i] = self.dial(addr)

    def close(self):
        for s in self.socks:
            try:
                s.close()
            except OSError:
                pass


def event_payload(tenant, seed, k, rows=8, feat=48):
    """A deterministic toy event: `rows` labels + a small image block.
    Same (tenant, seed, k) -> same values, on any client."""
    labels = [(seed + tenant * 31 + k * 7 + i) % 10 for i in range(rows)]
    imgs = [((seed * 131 + tenant * 17 + k * 13 + i) % 256) / 255.0
            for i in range(rows * feat)]
    return labels, imgs


def acc_bits(value):
    return f"{struct.unpack('<Q', struct.pack('<d', value))[0]:016x}"


def run_fleet(n_shards, n_tenants, events_per_tenant, arena_kb, seed,
              migrate_at=None, plan=None, client_id=0):
    """Serve the full drill against n_shards toy shards; returns the
    BENCH record. With migrate_at=(leg1_events), tenant 0 live-migrates
    off its home shard between the two legs. With a FaultPlan the
    client rides the injected chaos on stamped retries."""
    shards = [ToyShard(i, arena_kb * 1024) for i in range(n_shards)]
    for s in shards:
        s.start()
    client = Client([s.addr for s in shards], plan=plan, client_id=client_id)
    try:
        for g in range(n_tenants):
            client.admit(g, seed + g)
        rtts = []
        migrations = 0
        snapshot_bytes = 0
        migrate_ms = 0.0
        t0 = time.perf_counter()
        leg1 = migrate_at if migrate_at is not None else events_per_tenant
        for k in range(leg1):
            for g in range(n_tenants):
                labels, imgs = event_payload(g, seed, k)
                t1 = time.perf_counter()
                client.submit(g, labels, imgs)
                rtts.append(time.perf_counter() - t1)
        if migrate_at is not None and n_shards > 1:
            home = client.route(0)
            tm = time.perf_counter()
            snapshot_bytes = client.migrate(0, (home + 1) % n_shards)
            migrate_ms = (time.perf_counter() - tm) * 1e3
            migrations = 1
        for k in range(leg1, events_per_tenant):
            for g in range(n_tenants):
                labels, imgs = event_payload(g, seed, k)
                t1 = time.perf_counter()
                client.submit(g, labels, imgs)
                rtts.append(time.perf_counter() - t1)
        wall = time.perf_counter() - t0
        accs, lost = {}, 0
        for g in range(n_tenants):
            code, val = client.call_routed(g, enc_eval(g))
            if code != CODE_ACCURACY:
                lost += 1
                continue
            accs[str(g)] = acc_bits(val)
        code, stats0 = client.call(0, enc_stats())
        assert code == CODE_STATS
        for i in range(n_shards):
            client.call(i, enc_shutdown())
    finally:
        client.close()
    total = n_tenants * events_per_tenant
    rtts.sort()

    def pct(q):
        return rtts[min(len(rtts) - 1, int(q * len(rtts)))] * 1e3

    return {
        "bench": "shard",
        "protocol_version": VERSION,
        "shards": n_shards,
        "tenants": n_tenants,
        "events_per_tenant": events_per_tenant,
        "events": total,
        "events_per_sec": round(total / wall, 1),
        "submit_rtt_p50_ms": round(pct(0.50), 4),
        "submit_rtt_p99_ms": round(pct(0.99), 4),
        "sheds": 0,
        "migrations": migrations,
        "migration_ms": round(migrate_ms, 3),
        "snapshot_bytes": snapshot_bytes,
        "tenants_lost": lost,
        "stats_probe": {"shard": stats0["shard"],
                        "events_done": stats0["events_done"]},
        "determinism": {"acc_bits": accs},
        "client": {"net_retries": client.net_retries,
                   "duplicates": client.duplicates},
    }


def recovery_drill(arena_kb, seed):
    """Crash-mid-migration: shard 1 is scripted to die on its FIRST
    served frame — which, by homing every tenant on shard 0, is the
    migration's Restore (applied, never acknowledged). The drill is the
    recovery: rollback via the source tombstone, toy-supervisor restart
    of shard 1 (MTTR = detection -> replacement answers Ping), client
    re_resolve, retried migration, zero tenants lost."""
    arena = arena_kb * 1024
    shards = [ToyShard(0, arena), ToyShard(1, arena, crash_after_frames=1)]
    for s in shards:
        s.start()
    client = Client([s.addr for s in shards], client_id=7)
    tenants = [2, 4, 5, 6]  # all home on shard 0 of 2 (pinned placement)
    assert all(shard_of(g, 2) == 0 for g in tenants)
    net_retries_0 = 0
    try:
        for g in tenants:
            client.admit(g, seed + g)
            for k in range(2):
                labels, imgs = event_payload(g, seed, k)
                client.submit(g, labels, imgs)
        # migrate into the booby trap: the restore is applied, the
        # reply never comes, retries meet a dead listener
        detected = None
        try:
            client.migrate(2, 1)
            raise AssertionError("migration into the crashing shard "
                                 "must not succeed on the first try")
        except (OSError, RuntimeError):
            detected = time.perf_counter()
        net_retries_0 = client.net_retries
        assert net_retries_0 >= 1, "the dead shard must have cost retries"
        assert client.route(2) == 0, "failed migration must restore the pin"
        code, _ = client.call_routed(2, enc_eval(2))
        assert code == CODE_ACCURACY, "rollback must leave tenant 2 servable"

        # toy supervisor: same index, same (empty) state dir, fresh port
        shards[1] = ToyShard(1, arena)
        shards[1].start()
        while True:  # probe until the replacement answers a Ping
            try:
                s = socket.create_connection(shards[1].addr, timeout=1)
                client_handshake(s)
                send_frame(s, enc_ping())
                ok = dec_reply(recv_frame(s))[0] == CODE_OK
                s.close()
                if ok:
                    break
            except OSError:
                time.sleep(0.005)
        mttr_ms = (time.perf_counter() - detected) * 1e3

        client.re_resolve([s.addr for s in shards])
        client.migrate(2, 1)
        assert client.route(2) == 1
        for g in tenants:
            for k in range(2, 4):
                labels, imgs = event_payload(g, seed, k)
                client.submit(g, labels, imgs)
        lost = 0
        for g in tenants:
            code, _ = client.call_routed(g, enc_eval(g))
            if code != CODE_ACCURACY:
                lost += 1
        for i in range(2):
            client.call(i, enc_shutdown())
    finally:
        client.close()
    return {
        "restarts": 1,
        "failovers": 1,
        "mttr_ms": round(mttr_ms, 3),
        "net_retries": client.net_retries,
        "duplicates": client.duplicates,
        "tenants_lost": lost,
    }


# ---- selftest: pinned interop values ---------------------------------------

def selftest():
    # shard_of against the values pinned in rust/src/fleet/shard.rs tests
    assert [shard_of(t, 2) for t in range(8)] == [1, 1, 0, 1, 0, 0, 0, 1]
    assert [shard_of(t, 3) for t in range(8)] == [1, 2, 1, 0, 1, 2, 2, 0]
    assert shard_of(42, 4) == 1
    assert shard_of(1000, 4) == 0 and shard_of(1001, 4) == 0
    # frame layout v2: stamped admit is op + 8*4 + 1 + 4 + 8*2 = 54 bytes
    assert len(enc_admit(7, 11, 1, 4096, 8, 0.1, 2, 42)) == 54
    # stamped submit: op + tenant + stamp(16) + rows + labels + imglen + f32s
    p = enc_submit(3, 11, 2, [1, 2], [0.5, 0.25, 0.125])
    assert len(p) == 1 + 8 + 16 + 4 + 8 + 8 + 12
    assert p[0] == OP_SUBMIT
    # the new v2 ops are single-byte(+tenant) frames
    assert enc_ping() == bytes([OP_PING])
    assert len(enc_migrate_commit(9)) == 9 and len(enc_migrate_abort(9)) == 9
    # reply round-trips, including the v2 codes
    assert dec_reply(struct.pack("<Bd", CODE_ACCURACY, 0.625)) == (
        CODE_ACCURACY, 0.625)
    code, blob = dec_reply(struct.pack("<BQ", CODE_SNAPSHOT, 3) + b"abc")
    assert (code, blob) == (CODE_SNAPSHOT, b"abc")
    assert dec_reply(struct.pack("<B", CODE_DUPLICATE)) == (CODE_DUPLICATE,
                                                            None)
    assert dec_reply(struct.pack("<BQ", CODE_SHARD_DOWN, 50)) == (
        CODE_SHARD_DOWN, 50)
    # xoshiro256** regression pins (stability of the Python port; the
    # algorithm itself is a line-for-line port of rust/src/util/rng.rs)
    r = Rng(42)
    first = [r.next_u64() for _ in range(3)]
    assert first == [Rng(42).next_u64()] + first[1:], "Rng must be pure"
    assert Rng(42).next_u64() != Rng(43).next_u64()
    assert 0.0 <= Rng(7).f64() < 1.0
    assert Rng(7).below(10) < 10
    # fault decisions are pure in (seed, domain, op, attempt) and the
    # recovering preset never exceeds its streak bound
    plan = FaultPlan(11)
    assert plan.connect_fault(3, 0) == plan.connect_fault(3, 0)
    for op in range(64):
        for attempt in range(2, RETRY_ATTEMPTS):
            assert plan.frame_write_fault(op, attempt) is None, \
                "net_recovering streaks must stay under the retry budget"
            assert plan.frame_read_fault(op, attempt) is None
            assert plan.connect_fault(op, attempt) is None
    assert any(plan.frame_write_fault(op, 0) for op in range(64)), \
        "the preset must actually inject something"
    # toy tenant: snapshot round-trip is bit-exact and training is pure
    a = ToyTenant(42, 1024)
    a.train([1, 2, 3], b"imgs")
    b = ToyTenant.restore(a.snapshot())
    assert b.snapshot() == a.snapshot()
    a.train([4], b"more")
    b.train([4], b"more")
    assert a.accuracy() == b.accuracy()
    # dedup window: a re-delivered stamp is acked Duplicate, applied once
    sh = ToyShard(0, 1024)
    sh.listener.close()  # dispatch-only use
    assert sh.dispatch(enc_admit(5, 9, 1, 64, 8, 0.1, 2, 1))[0] == \
        CODE_ADMITTED
    labels, imgs = event_payload(5, 1, 0)
    assert sh.dispatch(enc_submit(5, 9, 2, labels, imgs))[0] == CODE_QUEUED
    assert sh.dispatch(enc_submit(5, 9, 2, labels, imgs))[0] == \
        CODE_DUPLICATE
    assert sh.tenants[5].events == 1, "duplicate must not re-apply"
    # two-phase migration: drain is idempotent, abort resurrects, commit
    # clears the tombstone
    blob1 = dec_reply(sh.dispatch(enc_drain(5)))[1]
    blob2 = dec_reply(sh.dispatch(enc_drain(5)))[1]
    assert blob1 == blob2 and 5 in sh.tombs and 5 not in sh.tenants
    assert sh.dispatch(enc_migrate_abort(5))[0] == CODE_OK
    assert 5 in sh.tenants and 5 not in sh.tombs
    assert ToyTenant.restore(blob1).snapshot() == sh.tenants[5].snapshot()
    dec_reply(sh.dispatch(enc_drain(5)))
    assert sh.dispatch(enc_migrate_commit(5))[0] == CODE_OK
    assert 5 not in sh.tombs and 5 not in sh.tenants
    print("shard_mirror: selftest OK")


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--events", type=int, default=64)
    ap.add_argument("--arena-kb", type=int, default=128)
    ap.add_argument("--seed", type=int, default=1000)
    ap.add_argument("--fault-seed", type=int, default=11)
    ap.add_argument("--out", default=None)
    ap.add_argument("--selftest", action="store_true")
    args = ap.parse_args()
    if args.selftest:
        selftest()
        return
    selftest()

    # the measured sharded run rides the seeded network chaos on a
    # stamped client; the control is a clean unstamped 1-shard serve —
    # identical accuracy bits are the bit-transparency contract
    plan = FaultPlan(args.fault_seed)
    sharded = run_fleet(args.shards, args.tenants, args.events,
                        args.arena_kb, args.seed,
                        migrate_at=args.events // 2,
                        plan=plan, client_id=1)
    control = run_fleet(1, args.tenants, args.events, args.arena_kb,
                        args.seed)
    if sharded["determinism"] != control["determinism"]:
        print("shard_mirror: FAIL: chaos run's accuracy bits diverge "
              "from the clean 1-shard control", file=sys.stderr)
        sys.exit(1)
    if sharded["client"]["net_retries"] < 1:
        print("shard_mirror: FAIL: the fault plan injected nothing",
              file=sys.stderr)
        sys.exit(1)

    drill = recovery_drill(args.arena_kb, args.seed)
    if drill["tenants_lost"] != 0:
        print("shard_mirror: FAIL: crash-mid-migration drill lost "
              f"{drill['tenants_lost']} tenant(s)", file=sys.stderr)
        sys.exit(1)
    sharded["fault_plan"] = {"preset": "net_recovering",
                             "seed": args.fault_seed}
    sharded["recovery"] = {
        "net_retries": sharded["client"]["net_retries"],
        "duplicates": sharded["client"]["duplicates"],
        "failovers": drill["failovers"],
        "restarts": drill["restarts"],
        "mttr_ms": drill["mttr_ms"],
        "tenants_lost": drill["tenants_lost"],
    }
    del sharded["client"]

    print(f"shard_mirror: {args.shards} shards x {args.tenants} tenants x "
          f"{args.events} events under net chaos (seed "
          f"{args.fault_seed}): {sharded['events_per_sec']} events/s, "
          f"submit RTT p50 {sharded['submit_rtt_p50_ms']} ms "
          f"p99 {sharded['submit_rtt_p99_ms']} ms")
    print(f"shard_mirror: migration: {sharded['snapshot_bytes']} snapshot "
          f"bytes in {sharded['migration_ms']} ms, "
          f"{sharded['tenants_lost']} tenants lost")
    rec = sharded["recovery"]
    print(f"shard_mirror: recovery: {rec['net_retries']} net retries, "
          f"{rec['duplicates']} duplicate acks, {rec['failovers']} "
          f"failover(s), restart MTTR {rec['mttr_ms']} ms, "
          f"{rec['tenants_lost']} tenants lost in the crash drill")
    print("shard_mirror: determinism.acc_bits identical to the clean "
          f"1-shard control ({len(control['determinism']['acc_bits'])} "
          "tenants)")
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            json.dump(sharded, f, indent=2)
            f.write("\n")
        print(f"shard_mirror: wrote {args.out}")


if __name__ == "__main__":
    main()
