#!/usr/bin/env python3
"""Dynamics mirror of the rust native backend + synthetic Core50-mini.

The build container ships no rust toolchain (see CHANGES.md), so — like
PR 1's tools/perf_mirror.c for the kernel engine — this script re-creates
the *algorithms* of `rust/src/runtime/{native,synthetic}.rs` in numpy at
the exact same sizes (MicroNet-32 arch, He init, INT-8 fake-quant frozen
stage, PTQ calibration, affine+ReLU adaptive stage with fused
fwd/BW-ERR/BW-GRAD/SGD, quantized replay buffer, NICv2-mini schedule) and
measures the learning dynamics the rust integration tests assert on:
loss decrease, accuracy lift over events, replay-starvation orderings.

RNG streams differ from the rust side (numpy vs xoshiro), so this checks
*dynamics*, not bit-equality; bit-level properties (quantizer, packing,
engine-vs-naive) are covered by in-crate property tests.

Usage: python3 tools/native_mirror.py [--frames 12] [--events 12] [--l 13]
"""

import argparse
import math
import time

import numpy as np

ARCH = [
    ("conv3x3", 3, 16, 2), ("dw", 16, 16, 1), ("pw", 16, 32, 1),
    ("dw", 32, 32, 2), ("pw", 32, 64, 1), ("dw", 64, 64, 1),
    ("pw", 64, 64, 1), ("dw", 64, 64, 2), ("pw", 64, 128, 1),
    ("dw", 128, 128, 1), ("pw", 128, 128, 1), ("dw", 128, 128, 2),
    ("pw", 128, 256, 1), ("dw", 256, 256, 1), ("pw", 256, 256, 1),
]
HW, NCLS, FEAT = 32, 10, 256
A_BITS = W_BITS = 8


# ---------------------------------------------------------------- kernels

def conv3x3(x, w, stride):  # x [B,H,W,C], w [3,3,Cin,Cout]
    b, h, wd, c = x.shape
    ho, wo = -(-h // stride), -(-wd // stride)
    xp = np.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = np.zeros((b, ho, wo, 9 * c), x.dtype)
    for ky in range(3):
        for kx in range(3):
            patch = xp[:, ky:ky + h:stride, kx:kx + wd:stride, :]
            cols[..., (ky * 3 + kx) * c:(ky * 3 + kx + 1) * c] = patch[:, :ho, :wo, :]
    return cols.reshape(b, ho, wo, 9 * c) @ w.reshape(9 * c, -1)


def depthwise(x, k, stride):  # k [3,3,C]
    b, h, wd, c = x.shape
    ho, wo = -(-h // stride), -(-wd // stride)
    xp = np.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    out = np.zeros((b, ho, wo, c), x.dtype)
    for ky in range(3):
        for kx in range(3):
            out += xp[:, ky:ky + h:stride, kx:kx + wd:stride, :][:, :ho, :wo, :] * k[ky, kx]
    return out


def depthwise_bw_err(g, k, stride, h, wd):
    b, ho, wo, c = g.shape
    dxp = np.zeros((b, h + 2, wd + 2, c), np.float32)
    for ky in range(3):
        for kx in range(3):
            dxp[:, ky:ky + h:stride, kx:kx + wd:stride, :][:, :ho, :wo, :] += g * k[ky, kx]
    return dxp[:, 1:h + 1, 1:wd + 1, :]


def depthwise_bw_grad(x, g, stride):
    b, h, wd, c = x.shape
    _, ho, wo, _ = g.shape
    xp = np.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    dk = np.zeros((3, 3, c), np.float32)
    for ky in range(3):
        for kx in range(3):
            dk[ky, kx] = (xp[:, ky:ky + h:stride, kx:kx + wd:stride, :][:, :ho, :wo, :] * g).sum((0, 1, 2))
    return dk


def fq_act(x, a_max, bits=A_BITS):
    levels = float(2 ** bits - 1)
    s = max(a_max / levels, 1e-12)
    return np.clip(np.floor(x / s), 0.0, levels) * s


def quant_weight_codes(w, bits=W_BITS):
    """Full-range affine weight quantization to signed integer levels,
    ROUND-TO-NEAREST-half-up (q = floor(w/S + 1/2)) — the one rule shared
    with rust (quant/requant.rs) and jax (compile/kernels/ref.py), pinned
    by tools/fixtures/weight_quant.json. Returns (levels int64, scale)."""
    w_min, w_max = min(float(w.min()), 0.0), max(float(w.max()), 0.0)
    s = max((w_max - w_min) / (2 ** bits - 1), 1e-12)
    lo = np.floor(w_min / s)
    q = np.clip(np.floor(w / s + 0.5), lo, lo + 2 ** bits - 1)
    return q.astype(np.int64), s


def fq_weight(w, bits=W_BITS):
    q, s = quant_weight_codes(w, bits)
    return (q * s).astype(np.float32)


def act_scale(a_max, bits=A_BITS):
    return max(a_max / float(2 ** bits - 1), 1e-12)


def requant_mult_shift(s):
    """Fixed-point multiplier+shift of a positive scale (31 significant
    bits) — quant/requant.rs::Requant::from_scale."""
    if not (s > 0 and math.isfinite(s)):
        return 0, 0
    mant, exp = math.frexp(s)
    mult = int(round(mant * 2 ** 31))
    if mult == 2 ** 31:
        mult = 2 ** 30
        exp += 1
    return mult, 31 - exp


def frozen_int(wq, a_max, x, l, bits=A_BITS):
    """The true-INT8 frozen prefix (the rust default since the integer
    pipeline): quantize the input once to UINT-8 codes, run every conv as
    an exact integer accumulation (float64 carries integers exactly up to
    2^53 — far above the 2^29 worst case — so BLAS dgemm IS the i32
    accumulator here), requantize each boundary with the fixed-point
    multiplier+shift, dequantize once at the split. `wq` is a list of
    (signed levels, scale) from quant_weight_codes."""
    levels = float(2 ** bits - 1)
    q = np.clip(np.floor(x / act_scale(1.0, bits)), 0.0, levels).astype(np.float64)
    in_a = 1.0
    for i, (kind, _ci, _co, st) in enumerate(ARCH[:min(l, len(ARCH))]):
        lev, w_scale = wq[i]
        acc = np.rint(conv_layer(kind, q, lev.astype(np.float64), st)).astype(np.int64)
        mult, shift = requant_mult_shift(
            act_scale(in_a, bits) * w_scale / act_scale(a_max[i], bits))
        if mult == 0 or shift >= 64:
            qi = np.zeros_like(acc)
        elif shift >= 0:
            qi = (np.maximum(acc, 0) * mult) >> shift
        else:
            qi = (np.maximum(acc, 0) * mult) << min(-shift, 62)
        q = np.clip(qi, 0, int(levels)).astype(np.float64)
        in_a = a_max[i]
    out = (q * np.float32(act_scale(in_a, bits))).astype(np.float32)
    if l >= len(ARCH):
        out = out.mean((1, 2))
    return out


# -------------------------------------------------------------- synthetic

def gen_world(seed, frames, train_sessions=6, test_sessions=2):
    rs = np.random.RandomState(seed)
    grids = rs.randint(30, 226, size=(NCLS, 4, 4, 3))
    shifts = rs.randint(-25, 26, size=(train_sessions + test_sessions,))

    def images(class_, session, n, rng):
        g = np.kron(grids[class_], np.ones((8, 8, 1)))  # 32x32x3
        imgs = g[None] + shifts[session] + rng.randint(-18, 19, size=(n, HW, HW, 3))
        return np.clip(imgs, 0, 255).astype(np.uint8)

    train, test = [], []
    for c in range(NCLS):
        for s in range(train_sessions):
            rng = np.random.RandomState(seed * 1000 + c * 131 + s)
            train.append((c, s, images(c, s, frames, rng)))
        for ts in range(test_sessions):
            s = train_sessions + ts
            rng = np.random.RandomState(seed * 1000 + c * 131 + s)
            test.append((c, images(c, s, frames, rng)))
    return train, test


def init_net(seed):
    rs = np.random.RandomState(seed + 77)
    ws = []
    for kind, cin, cout, _s in ARCH:
        if kind == "conv3x3":
            w = rs.randn(3, 3, cin, cout) * (2.0 / (9 * cin)) ** 0.5
        elif kind == "dw":
            w = rs.randn(3, 3, cin) * (2.0 / 9.0) ** 0.5
        else:
            w = rs.randn(cin, cout) * (2.0 / cin) ** 0.5
        ws.append(w.astype(np.float32))
    head = (rs.randn(FEAT, NCLS) * (1.0 / FEAT) ** 0.5).astype(np.float32)
    return normalize_net(ws, seed), head


def normalize_net(ws, seed):
    """Layer-wise weight standardization on seeded noise probes — the
    random-net analogue of the folded-BN scales the real pipeline gets
    from pretraining: each layer's post-ReLU std is normalized to 1 so
    activations stay O(1) at any depth (matches the rust NativeBackend)."""
    rs = np.random.RandomState(seed + 991)
    x = rs.rand(16, HW, HW, 3).astype(np.float32)
    ws = [w.copy() for w in ws]
    for i, (kind, _ci, _co, s) in enumerate(ARCH):
        y = np.maximum(conv_layer(kind, x, ws[i], s), 0.0)
        sd = max(float(y.std()), 1e-6)
        ws[i] /= sd
        x = y / sd
    return ws


def conv_layer(kind, x, w, stride):
    if kind == "conv3x3":
        return conv3x3(x, w, stride)
    if kind == "dw":
        return depthwise(x, w, stride)
    b, h, wd, c = x.shape
    return (x.reshape(-1, c) @ w).reshape(b, h, wd, -1)


def calibrate(ws_q, probes):
    a_max = [0.0] * len(ARCH)
    x = fq_act(probes, 1.0)
    for i, (kind, _ci, _co, s) in enumerate(ARCH):
        y = np.maximum(conv_layer(kind, x, ws_q[i], s), 0.0)
        a_max[i] = max(a_max[i], float(y.max()))
        x = fq_act(y, max(a_max[i], 1e-6))
    pooled = float(x.mean((1, 2)).max())
    return a_max, pooled


def frozen(ws, ws_q, a_max, x, l, int8):
    if int8:
        x = fq_act(x, 1.0)
    for i, (kind, _ci, _co, s) in enumerate(ARCH[:min(l, len(ARCH))]):
        y = np.maximum(conv_layer(kind, x, ws_q[i] if int8 else ws[i], s), 0.0)
        if int8:
            y = fq_act(y, a_max[i])
        x = y
    if l >= len(ARCH):
        x = x.mean((1, 2))
    return x


# ------------------------------------------------------- adaptive training

def adaptive_forward(params, lat, l, stash=None):
    x = lat
    n_conv = len(ARCH) - l if l < len(ARCH) else 0
    for li in range(n_conv):
        kind, _ci, _co, s = ARCH[l + li]
        bb, g, w = params[3 * li], params[3 * li + 1], params[3 * li + 2]
        z = conv_layer(kind, x, w, s)
        a = np.maximum(z * g + bb, 0.0)
        if stash is not None:
            stash.append((x, z, a))
        x = a
    feats = x.mean((1, 2)) if n_conv else x
    hb, hw_ = params[3 * n_conv], params[3 * n_conv + 1]
    return feats @ hw_ + hb, feats


def train_step(params, lat, labels, lr, l):
    n_conv = len(ARCH) - l if l < len(ARCH) else 0
    stash = []
    logits, feats = adaptive_forward(params, lat, l, stash)
    b = len(labels)
    m = logits.max(1, keepdims=True)
    lse = m + np.log(np.exp(logits - m).sum(1, keepdims=True))
    p = np.exp(logits - lse)
    loss = float((lse[:, 0] - logits[np.arange(b), labels]).mean())
    correct = int((logits.argmax(1) == labels).sum())
    dlogits = p.copy()
    dlogits[np.arange(b), labels] -= 1.0
    dlogits /= b
    hb_i, hw_i = 3 * n_conv, 3 * n_conv + 1
    d_hw = feats.T @ dlogits
    d_hb = dlogits.sum(0)
    dfeat = dlogits @ params[hw_i].T
    grads = {hb_i: d_hb, hw_i: d_hw}
    if n_conv:
        x_last = stash[-1][2]
        hw2 = x_last.shape[1] * x_last.shape[2]
        da = np.broadcast_to(dfeat[:, None, None, :] / hw2, x_last.shape).astype(np.float32)
        for li in reversed(range(n_conv)):
            kind, _ci, _co, s = ARCH[l + li]
            x, z, a = stash[li]
            g = params[3 * li + 1]
            dy = da * (a > 0)
            grads[3 * li] = dy.sum((0, 1, 2))
            grads[3 * li + 1] = (dy * z).sum((0, 1, 2))
            dz = dy * g
            w = params[3 * li + 2]
            if kind == "pw":
                bb_, h_, w_, c_ = dz.shape
                da = (dz.reshape(-1, dz.shape[-1]) @ w.T).reshape(x.shape)
                grads[3 * li + 2] = x.reshape(-1, x.shape[-1]).T @ dz.reshape(-1, dz.shape[-1])
            else:
                da = depthwise_bw_err(dz, w, s, x.shape[1], x.shape[2])
                grads[3 * li + 2] = depthwise_bw_grad(x, dz, s)
    for i, gr in grads.items():
        params[i] = params[i] - lr * gr.astype(np.float32)
    return loss, correct


def init_params(ws, head, l):
    params = []
    n_conv = len(ARCH) - l if l < len(ARCH) else 0
    for li in range(n_conv):
        cout = ARCH[l + li][2]
        params += [np.zeros(cout, np.float32), np.ones(cout, np.float32), ws[l + li].copy()]
    params += [np.zeros(NCLS, np.float32), head.copy()]
    return params


# ------------------------------------------------------------------ replay

class Replay:
    def __init__(self, cap, elems, bits, a_max):
        self.cap, self.elems, self.bits, self.a_max = cap, elems, bits, a_max
        self.lat = np.zeros((cap, elems), np.float32)
        self.lab = np.full(cap, -1, np.int32)
        self.filled = []

    def write(self, slot, v, label):
        if self.bits < 32:
            levels = 2 ** self.bits - 1
            s = max(self.a_max / levels, 1e-12)
            v = np.clip(np.floor(v / s), 0, levels) * s
        if self.lab[slot] == -1:
            self.filled.append(slot)
        self.lat[slot], self.lab[slot] = v, label

    def init_fill(self, lats, labs, rs):
        take = min(len(labs), self.cap)
        for slot, src in enumerate(rs.choice(len(labs), take, replace=False)):
            self.write(slot, lats[src], labs[src])

    def event_update(self, lats, labs, ev, rs):
        h = min(max(self.cap // ev, 1), len(labs), self.cap)
        dst = rs.choice(self.cap, h, replace=False)
        src = rs.choice(len(labs), h, replace=False)
        for d, s_ in zip(dst, src):
            self.write(d, lats[s_], labs[s_])
        return h

    def sample(self, k, rs):
        slots = [self.filled[i] for i in rs.randint(0, len(self.filled), k)]
        return self.lat[slots], self.lab[slots]


# ---------------------------------------------------------------- protocol

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=12)
    ap.add_argument("--events", type=int, default=12)
    ap.add_argument("--l", type=int, default=13)
    ap.add_argument("--n-lr", type=int, default=256)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--int8", type=int, default=1)
    args = ap.parse_args()
    if args.frames < 8:
        ap.error("--frames must be >= 8 (the training loop draws batch_new=8 new "
                 "latents per step, so smaller events never form a batch)")
    t0 = time.time()

    train, test = gen_world(args.seed, args.frames)
    ws, head = init_net(args.seed)
    ws_q = [fq_weight(w) for w in ws]
    wq = [quant_weight_codes(w) for w in ws]
    initial = [(c, s, im) for (c, s, im) in train if c < 4 and s < 2]
    probes = np.concatenate([im for (_c, _s, im) in initial])[:96].astype(np.float32) / 255.0
    a_max, pooled = calibrate(ws_q, probes)
    print(f"[mirror] calibrated a_max[l-1]={a_max[args.l-1]:.3f} pooled={pooled:.3f}"
          f" ({time.time()-t0:.1f}s)")

    l, int8 = args.l, bool(args.int8)
    lat_amax = pooled if l >= len(ARCH) else a_max[l - 1]

    def latents(imgs):
        x = imgs.astype(np.float32) / 255.0
        if int8:  # the true-INT8 default path
            return frozen_int(wq, a_max, x, l).reshape(len(imgs), -1)
        return frozen(ws, ws_q, a_max, x, l, False).reshape(len(imgs), -1)

    test_lat = np.concatenate([latents(im) for (_c, im) in test])
    test_lab = np.concatenate([np.full(len(im), c) for (c, im) in test])
    elems = test_lat.shape[1]
    print(f"[mirror] l={l} latent elems={elems} test={len(test_lab)} ({time.time()-t0:.1f}s)")

    params = init_params(ws, head, l)

    def evaluate():
        logits, _ = adaptive_forward(
            params, test_lat.reshape((len(test_lab),) + lat_shape(l)), l)
        return float((logits.argmax(1) == test_lab).mean())

    def lat_shape(l_):
        if l_ >= len(ARCH):
            return (FEAT,)
        hw = HW
        for _k, _ci, _co, s in ARCH[:l_]:
            hw = -(-hw // s)
        return (hw, hw, ARCH[l_][1])

    rs = np.random.RandomState(args.seed + 5)
    buf = Replay(args.n_lr, elems, args.bits, lat_amax)
    init_lat = np.concatenate([latents(im) for (_c, _s, im) in initial])
    init_lab = np.concatenate([np.full(len(im), c) for (c, _s, im) in initial])
    buf.init_fill(init_lat, init_lab, rs)
    print(f"[mirror] buffer {len(buf.filled)}/{args.n_lr} filled")

    acc0 = evaluate()
    print(f"[mirror] initial acc {acc0:.3f} ({time.time()-t0:.1f}s)")

    events = [(c, s) for (c, s, _im) in train if not (c < 4 and s < 2)]
    rs.shuffle(events)
    events = events[:args.events]
    shape = lat_shape(l)
    first_losses, last_losses = [], []
    for ei, (c, s) in enumerate(events, 1):
        imgs = next(im for (cc, ss, im) in train if cc == c and ss == s)
        ev_lat = latents(imgs)
        ev_lab = np.full(len(imgs), c)
        n = len(imgs)
        losses = []
        correct = seen = 0
        for _ep in range(args.epochs):
            order = rs.permutation(n)
            pos = 0
            while pos + 8 <= n:
                pick = order[pos:pos + 8]
                rl, rb = buf.sample(56, rs)
                bl = np.concatenate([ev_lat[pick], rl]).reshape((64,) + shape)
                bb = np.concatenate([ev_lab[pick], rb]).astype(np.int64)
                loss, corr = train_step(params, bl.astype(np.float32), bb, args.lr, l)
                losses.append(loss)
                correct += corr
                seen += 64
                pos += 8
        buf.event_update(ev_lat, ev_lab, ei, rs)
        first_losses.append(losses[0])
        last_losses.append(losses[-1])
        acc = evaluate()
        print(f"[mirror] event {ei:2d} class {c} sess {s}: loss {losses[0]:.3f}->{losses[-1]:.3f}"
              f" train_acc {correct/seen:.3f} test_acc {acc:.3f} ({time.time()-t0:.0f}s)")
    accf = evaluate()
    print(f"[mirror] RESULT l={l} int8={int8} Q={args.bits}: acc {acc0:.3f} -> {accf:.3f}"
          f" (delta {accf-acc0:+.3f}), mean first/last loss"
          f" {np.mean(first_losses):.3f}/{np.mean(last_losses):.3f}")


if __name__ == "__main__":
    main()
