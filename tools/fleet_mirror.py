#!/usr/bin/env python3
"""Measurement mirror of the fleet serving layer (rust/src/fleet/).

The build container ships no rust toolchain (see CHANGES.md), so — like
PR 1's tools/perf_mirror.c and PR 2's tools/native_mirror.py — this
script re-creates the fleet hot path in numpy at the exact same sizes and
measures what BENCH_fleet.json reports: events/sec and per-event latency
p50/p99 at 1 vs 8 vs 64 tenants, plus the governor outcome (8->7-bit
demotions, shrinks, bytes in use) of admitting 64 tenants whose nominal
footprints exceed the 64 MB budget.

Mirrored per event (identical math to the rust side, numpy-vectorized):
one coalesced frozen forward across up to 8 queued events (MicroNet-32,
INT-8 fake-quant, split l=15), then per-tenant head training — 2 epochs
x 3 steps of batch 64 (8 new + 56 replays drawn from the tenant's
UINT-8/7 replay buffer) — and the AR1* replay update. The governor
arithmetic (admission cost, demotion/shrink byte deltas, coldest-first
order) is replicated exactly from rust/src/fleet/governor.rs.

The mirror is single-threaded (GIL), so its events/sec UNDERSTATES the
worker-pool rust implementation; `cargo run --release --example
fleet_serving` regenerates the authoritative numbers wherever a rust
toolchain exists.

Usage: python3 tools/fleet_mirror.py [--events 3] [--frames 30]
"""

import argparse
import json
import time

import numpy as np

import native_mirror as nm

L = 15                 # head-only split: latent = pooled 256-dim feature
FEAT = nm.FEAT
B_NEW, B_TRAIN = 8, 64
COALESCE = 8
BUDGET = 64 * 1024 * 1024
N_LR = 4096
MIN_BITS, MIN_SLOTS = 7, 16


# ---- governor byte arithmetic (mirrors ReplayBuffer::bytes_for etc.) ----

def arena_bytes(cap, elems, bits):
    if bits == 32:
        return cap * elems * 4
    return (cap * elems * bits + 7) // 8


def buffer_bytes(cap, elems, bits):
    scratch = 0 if bits == 32 else elems
    return arena_bytes(cap, elems, bits) + cap * 8 + scratch


def tenant_overhead():
    # adaptive params + grads (head only: FEAT*NCLS + NCLS) + one batch of
    # training activations ((lr_elems + ncls) * batch * 4) — matches
    # models/memory.rs::breakdown at n_lr=0 minus the frozen stage
    head_w = FEAT * nm.NCLS + nm.NCLS
    act = (FEAT + nm.NCLS) * B_TRAIN * 4
    return head_w * 4 * 2 + act


def shared_backbone_bytes():
    n = 0
    for kind, cin, cout, _s in nm.ARCH:
        n += 9 * cin * cout if kind == "conv3x3" else (9 * cin if kind == "dw" else cin * cout)
    return n  # INT-8: one byte per weight


def governed_admissions(n_tenants):
    """Replay the governor's admission sequence exactly: returns
    (demotions, shrinks, bytes_in_use)."""
    overhead = tenant_overhead()
    tenants = []  # [bits, slots, last_active]
    in_use = shared_backbone_bytes()
    demotions = shrinks = 0
    clock = 0
    for _ in range(n_tenants):
        needed = overhead + buffer_bytes(N_LR, FEAT, 8)
        free = BUDGET - in_use
        # pass 1: demote coldest 8-bit tenants to 7
        order = sorted(range(len(tenants)), key=lambda i: (tenants[i][2], i))
        for i in order:
            if free >= needed:
                break
            bits, slots, _ = tenants[i]
            if bits == 8:
                gain = arena_bytes(slots, FEAT, 8) - arena_bytes(slots, FEAT, 7)
                tenants[i][0] = 7
                in_use -= gain
                free += gain
                demotions += 1
        # pass 2: shrink coldest, halving to the floor
        progressed = True
        while free < needed and progressed:
            progressed = False
            for i in order:
                if free >= needed:
                    break
                bits, slots, _ = tenants[i]
                target = max(slots // 2, MIN_SLOTS)
                if target >= slots:
                    continue
                gain = buffer_bytes(slots, FEAT, bits) - buffer_bytes(target, FEAT, bits)
                tenants[i][1] = target
                in_use -= gain
                free += gain
                shrinks += 1
                progressed = True
        assert free >= needed, "mirror: budget infeasible"
        tenants.append([8, N_LR, clock])
        in_use += needed
        clock += 1
    return demotions, shrinks, in_use


# ---- the serving loop mirror -------------------------------------------

def serve(n_tenants, events_per_tenant, frames, seed=7):
    train, _test = nm.gen_world(seed, frames)
    ws, head = nm.init_net(seed)
    ws_q = [nm.fq_weight(w) for w in ws]
    init_events = [(c, s, imgs) for (c, s, imgs) in train if c < 4 and s < 2]
    init_imgs = np.concatenate([e[2] for e in init_events]).astype(np.float32) / 255.0
    init_labs = np.concatenate([np.full(len(e[2]), e[0], np.int32) for e in init_events])
    a_max, pooled = nm.calibrate(ws_q, init_imgs[:96])
    init_lat = nm.frozen(ws, ws_q, a_max, init_imgs, L, True)

    tenants = []
    for t in range(n_tenants):
        rep = nm.Replay(N_LR, FEAT, 8, pooled)
        rep.init_fill(init_lat, init_labs, np.random.RandomState(100 + t))
        tenants.append({"params": nm.init_params(ws, head, L), "rep": rep,
                        "rs": np.random.RandomState(1000 + t), "events": 0})

    # round-robin event stream: (tenant, class, session)
    stream = []
    pool = [(c, s) for c in range(nm.NCLS) for s in range(6) if not (c < 4 and s < 2)]
    for e in range(events_per_tenant):
        for t in range(n_tenants):
            c, s = pool[(t * 7 + e) % len(pool)]
            stream.append((t, c, s))
    frames_of = {(c, s): imgs for (c, s, imgs) in train}

    lat_ms = []
    t0 = time.perf_counter()
    frozen_calls = 0
    for i in range(0, len(stream), COALESCE):
        batch = stream[i:i + COALESCE]
        te0 = time.perf_counter()
        imgs = np.concatenate([frames_of[(c, s)] for (_t, c, s) in batch]).astype(np.float32) / 255.0
        lats = nm.frozen(ws, ws_q, a_max, imgs, L, True)  # ONE coalesced call
        frozen_calls += 1
        row = 0
        for (t, c, _s) in batch:
            n = frames
            ev_lat, ev_lab = lats[row:row + n], np.full(n, c, np.int32)
            row += n
            ten = tenants[t]
            ten["events"] += 1
            for _ep in range(2):
                order = ten["rs"].permutation(n)
                for pos in range(0, n - B_NEW + 1, B_NEW):
                    pick = order[pos:pos + B_NEW]
                    r_lat, r_lab = ten["rep"].sample(B_TRAIN - B_NEW, ten["rs"])
                    bl = np.concatenate([ev_lat[pick], r_lat])
                    bb = np.concatenate([ev_lab[pick], r_lab])
                    nm.train_step(ten["params"], bl, bb, 0.1, L)
            ten["rep"].event_update(ev_lat, ev_lab, ten["events"], ten["rs"])
        # charge the whole coalesced batch's wall to each of its events
        # (single-threaded mirror: stage A+B are serial)
        per_ev = (time.perf_counter() - te0) * 1e3 / len(batch)
        lat_ms.extend([per_ev] * len(batch))
    wall = time.perf_counter() - t0
    lat_ms.sort()
    n = len(lat_ms)
    pick = lambda q: lat_ms[min(max(int(np.ceil(q * n)) - 1, 0), n - 1)]
    return {
        "tenants": n_tenants,
        "events": n,
        "events_per_sec": round(n / wall, 3),
        "p50_ms": round(pick(0.50), 3),
        "p99_ms": round(pick(0.99), 3),
        "mean_events_per_frozen_call": round(n / frozen_calls, 3),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=3)
    ap.add_argument("--frames", type=int, default=30)
    args = ap.parse_args()

    grid = []
    for n in (1, 8, 64):
        r = serve(n, args.events, args.frames)
        print(f"tenants {n:3}: {r['events_per_sec']:8.1f} events/s  "
              f"p50 {r['p50_ms']:.1f} ms  p99 {r['p99_ms']:.1f} ms", flush=True)
        grid.append(r)
    demotions, shrinks, in_use = governed_admissions(64)
    out = {
        "description": (
            "Fleet serving throughput/latency: N concurrent QLR-CL tenants on one shared "
            "frozen backbone (rust/src/fleet/), events/sec and per-event latency vs tenant "
            "count, plus the governor outcome of the pressured max-tenant run."),
        "methodology": (
            "tools/fleet_mirror.py — single-threaded numpy mirror of the fleet hot path at "
            "identical sizes (MicroNet-32, l=15, N_LR=4096 UINT-8, 30-frame events, 2 epochs "
            "x 3 steps of batch 64, coalesce 8) on this 2-core container; no rust toolchain "
            "ships in the build image, so these UNDERSTATE the worker-pool rust numbers. "
            "`cargo run --release --example fleet_serving` regenerates authoritative numbers "
            "(and asserts N=1 parity + >=1 governor demotion); `cargo bench --bench fleet` "
            "writes results/bench_fleet.tsv."),
        "profile": "full (mirror)",
        "grid": grid,
        "governed_max_run": {
            "budget_mb": 64,
            "tenants_admitted": 64,
            "demotions_8_to_7": demotions,
            "shrinks": shrinks,
            "bytes_in_use_mb": round(in_use / (1024 * 1024), 3),
            "note": ("governor arithmetic replayed exactly from "
                     "rust/src/fleet/governor.rs; accuracy/parity are asserted by the rust "
                     "example and tests, not mirrored here"),
        },
    }
    with open("BENCH_fleet.json", "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"governed 64-tenant run: {demotions} demotions, {shrinks} shrinks, "
          f"{in_use / 1048576:.1f} MiB in use — wrote BENCH_fleet.json")


if __name__ == "__main__":
    main()
