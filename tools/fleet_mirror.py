#!/usr/bin/env python3
"""Measurement mirror of the fleet serving layer (rust/src/fleet/).

The build container ships no rust toolchain (see CHANGES.md), so — like
PR 1's tools/perf_mirror.c and PR 2's tools/native_mirror.py — this
script re-creates the fleet hot path in numpy at the exact same sizes and
measures what BENCH_fleet.json reports: events/sec and per-event latency
p50/p99 at 1 vs 8 vs 64 tenants, plus the governor outcome (8->7-bit
demotions, shrinks, bytes in use) of admitting 64 tenants whose nominal
footprints exceed the 64 MB budget.

Mirrored per event (identical math to the rust side, numpy-vectorized):
one coalesced frozen forward across up to 8 queued events (MicroNet-32,
split l=15, on the TRUE-INT8 integer pipeline — u8 activation codes,
round-to-nearest i8 weight levels, exact integer accumulation carried in
float64, fixed-point multiplier+shift requantization; see
native_mirror.frozen_int), then per-tenant head training — 2 epochs
x 3 steps of batch 64 (8 new + 56 replays drawn from the tenant's
UINT-8/7 replay buffer) — and the AR1* replay update. The governor
arithmetic (admission cost, demotion/shrink byte deltas, coldest-first
order) is replicated exactly from rust/src/fleet/governor.rs.

The mirror is single-threaded (GIL), so its events/sec UNDERSTATES the
worker-pool rust implementation; `cargo run --release --example
fleet_serving` regenerates the authoritative numbers wherever a rust
toolchain exists.

Usage: python3 tools/fleet_mirror.py [--events 3] [--frames 30]
"""

import argparse
import json
import os
import pickle
import queue
import tempfile
import threading
import time
import zlib

import numpy as np

import native_mirror as nm

L = 15                 # head-only split: latent = pooled 256-dim feature
FEAT = nm.FEAT
B_NEW, B_TRAIN = 8, 64
COALESCE = 8
BUDGET = 64 * 1024 * 1024
N_LR = 4096
MIN_BITS, MIN_SLOTS = 7, 16
LOW_WM, HIGH_WM = 0.60, 0.85   # governor watermark defaults


# ---- telemetry mirror (rust/src/telemetry/) ------------------------------
#
# The same log2 fixed-bucket histogram + nearest-rank percentile math as
# rust/src/telemetry/hist.rs: bucket b covers [2^b, 2^(b+1)) ns, rank =
# ceil(q*n) clamped to [1, n], percentile = the upper bound of the
# bucket holding that rank. With no rust toolchain in the container this
# mirror IS the measurement path for BENCH_fleet.json's telemetry block.

def bucket_of(ns):
    return max(int(ns), 1).bit_length() - 1


def bucket_upper_ns(b):
    return (1 << (b + 1)) - 1 if b < 63 else (1 << 64) - 1


class Hist:
    """Mirror of telemetry::hist::Histogram (single-threaded, no atomics
    needed under the GIL)."""

    def __init__(self):
        self.counts = [0] * 64
        self.n = 0
        self.sum_ns = 0
        self.max_ns = 0

    def record(self, ns):
        ns = int(ns)
        self.counts[bucket_of(ns)] += 1
        self.n += 1
        self.sum_ns += ns
        self.max_ns = max(self.max_ns, ns)

    def percentile_ns(self, q):
        # bucket upper bound, clamped to the exact observed max so the
        # p50 <= p95 <= p99 <= max ordering always holds (same clamp as
        # Histogram::percentile_ns)
        if self.n == 0:
            return 0
        rank = min(max(int(np.ceil(q * self.n)), 1), self.n)
        cum = 0
        for b in range(64):
            cum += self.counts[b]
            if cum >= rank:
                return min(bucket_upper_ns(b), self.max_ns)
        return min(bucket_upper_ns(63), self.max_ns)

    def summary(self):
        r6 = lambda v: round(v, 6)
        return {
            "n": self.n,
            "p50_ms": r6(self.percentile_ns(0.50) / 1e6),
            "p95_ms": r6(self.percentile_ns(0.95) / 1e6),
            "p99_ms": r6(self.percentile_ns(0.99) / 1e6),
            "max_ms": r6(self.max_ns / 1e6),
            "mean_ms": r6((self.sum_ns / self.n if self.n else 0.0) / 1e6),
        }


class Telem:
    """Span + histogram + counter collector for one mirrored run; exports
    the BENCH telemetry block and a Chrome trace_event artifact."""

    def __init__(self):
        self.epoch = time.perf_counter_ns()
        self.hists = {"dispatch": Hist(), "serve": Hist(), "eval": Hist()}
        self.counters = {}
        self.spans = []  # (name, t0_ns, dur_ns, args)

    def now_ns(self):
        return time.perf_counter_ns() - self.epoch

    def count(self, name, v=1):
        self.counters[name] = self.counters.get(name, 0) + v

    def span(self, name, t0_ns, dur_ns, **args):
        self.spans.append((name, int(t0_ns), int(dur_ns), args))

    def block(self, robustness):
        out = {
            "events_recorded": len(self.spans),
            "events_dropped": 0,
            "threads_traced": 1,
        }
        for name, h in self.hists.items():
            if h.n:
                out[name] = h.summary()
        out["counters"] = {k: int(v) for k, v in sorted(self.counters.items())}
        out["robustness"] = robustness
        out["note"] = (
            "single-threaded numpy mirror of rust/src/telemetry/ (same log2 "
            "buckets + nearest-rank percentiles as hist.rs); the rust example "
            "regenerates authoritative figures with per-worker rings and the "
            "per-layer Fig. 8 table wherever a toolchain exists")
        return out

    def chrome_trace(self):
        evs = [{
            "ph": "M", "name": "thread_name", "pid": 1, "tid": 1,
            "args": {"name": "mirror-serve"},
        }]
        for name, t0, dur, args in sorted(self.spans, key=lambda s: s[1]):
            evs.append({
                "ph": "X", "name": name, "pid": 1, "tid": 1,
                "ts": round(t0 / 1e3, 3), "dur": round(dur / 1e3, 3),
                "args": args,
            })
        return {
            "traceEvents": evs,
            "displayTimeUnit": "ms",
            "otherData": {"events_dropped": "0", "source": "tools/fleet_mirror.py"},
        }


# ---- governor byte arithmetic (mirrors ReplayBuffer::bytes_for etc.) ----

def arena_bytes(cap, elems, bits):
    if bits == 32:
        return cap * elems * 4
    return (cap * elems * bits + 7) // 8


def buffer_bytes(cap, elems, bits):
    scratch = 0 if bits == 32 else elems
    return arena_bytes(cap, elems, bits) + cap * 8 + scratch


def tenant_overhead():
    # adaptive params + grads (head only: FEAT*NCLS + NCLS) + one batch of
    # training activations ((lr_elems + ncls) * batch * 4) — matches
    # models/memory.rs::breakdown at n_lr=0 minus the frozen stage
    head_w = FEAT * nm.NCLS + nm.NCLS
    act = (FEAT + nm.NCLS) * B_TRAIN * 4
    return head_w * 4 * 2 + act


def shared_backbone_bytes():
    n = 0
    for kind, cin, cout, _s in nm.ARCH:
        n += 9 * cin * cout if kind == "conv3x3" else (9 * cin if kind == "dw" else cin * cout)
    return n  # INT-8: one byte per weight


def governed_admissions(n_tenants):
    """Replay the governor's admission sequence exactly: returns
    (demotions, shrinks, bytes_in_use)."""
    overhead = tenant_overhead()
    tenants = []  # [bits, slots, last_active]
    in_use = shared_backbone_bytes()
    demotions = shrinks = 0
    clock = 0
    for _ in range(n_tenants):
        needed = overhead + buffer_bytes(N_LR, FEAT, 8)
        free = BUDGET - in_use
        # pass 1: demote coldest 8-bit tenants to 7
        order = sorted(range(len(tenants)), key=lambda i: (tenants[i][2], i))
        for i in order:
            if free >= needed:
                break
            bits, slots, _ = tenants[i]
            if bits == 8:
                gain = arena_bytes(slots, FEAT, 8) - arena_bytes(slots, FEAT, 7)
                tenants[i][0] = 7
                in_use -= gain
                free += gain
                demotions += 1
        # pass 2: shrink coldest, halving to the floor
        progressed = True
        while free < needed and progressed:
            progressed = False
            for i in order:
                if free >= needed:
                    break
                bits, slots, _ = tenants[i]
                target = max(slots // 2, MIN_SLOTS)
                if target >= slots:
                    continue
                gain = buffer_bytes(slots, FEAT, bits) - buffer_bytes(target, FEAT, bits)
                tenants[i][1] = target
                in_use -= gain
                free += gain
                shrinks += 1
                progressed = True
        assert free >= needed, "mirror: budget infeasible"
        tenants.append([8, N_LR, clock])
        in_use += needed
        clock += 1
    return demotions, shrinks, in_use


def snapshot_bytes(cap, elems, bits, filled):
    """Exact encoded size of one cold-tier tenant snapshot at the
    head-only split, replayed from rust/src/fleet/snapshot.rs::encode
    (24-byte header; config 34; next_seq 8; metrics 56; rng 32; the two
    head tensors; the replay block)."""
    params = 4
    for name, shape in (("layer0.b", (nm.NCLS,)), ("layer0.w", (FEAT, nm.NCLS))):
        n = int(np.prod(shape))
        params += 4 + len(name) + 1 + 4 * len(shape) + 8 + 4 * n
    replay = (8 + 8 + 1) + (1 + 4 + 8 + arena_bytes(cap, elems, bits)) \
        + 4 * cap + 8 + 4 * filled
    parked = 8  # count; admission-time spills are always quiesced
    return 24 + 34 + 8 + 56 + 32 + params + replay + parked


def tiered_admissions(n_tenants, filled, budget=BUDGET):
    """Replay the three-tier admission ladder exactly (demote -> spill ->
    shrink, coldest first — governor.rs::plan_relief in DegradeAndSpill
    mode). Returns (spills, demotions, tenant states, in_use, disk)."""
    overhead = tenant_overhead()
    tenants = []  # per tenant: {"bits", "slots", "clock", "resident"}
    in_use = shared_backbone_bytes()
    disk = demotions = spills = 0
    clock = 0
    for _ in range(n_tenants):
        needed = overhead + buffer_bytes(N_LR, FEAT, 8)
        free = budget - in_use
        order = sorted(
            (i for i, t in enumerate(tenants) if t["resident"]),
            key=lambda i: (tenants[i]["clock"], i),
        )
        # pass 1: demote coldest 8-bit residents
        for i in order:
            if free >= needed:
                break
            t = tenants[i]
            if t["bits"] == 8:
                gain = arena_bytes(t["slots"], FEAT, 8) - arena_bytes(t["slots"], FEAT, 7)
                t["bits"] = 7
                in_use -= gain
                free += gain
                demotions += 1
        # pass 2: spill coldest residents whole (lossless)
        for i in order:
            if free >= needed:
                break
            t = tenants[i]
            if not t["resident"]:
                continue
            gain = overhead + buffer_bytes(t["slots"], FEAT, t["bits"])
            t["resident"] = False
            disk += snapshot_bytes(t["slots"], FEAT, t["bits"], filled)
            in_use -= gain
            free += gain
            spills += 1
        assert free >= needed, "mirror: tiered budget infeasible"
        tenants.append({"bits": 8, "slots": N_LR, "clock": clock, "resident": True})
        in_use += needed
        clock += 1
    return spills, demotions, tenants, in_use, disk


# ---- the serving loop mirror -------------------------------------------

def eval_mean_accuracy(tenant_params, wq, a_max, test, telem=None):
    test_imgs = np.concatenate([imgs for (_c, imgs) in test]).astype(np.float32) / 255.0
    test_labs = np.concatenate([np.full(len(imgs), c, np.int32) for (c, imgs) in test])
    test_lat = nm.frozen_int(wq, a_max, test_imgs, L)
    accs = []
    for i, params in enumerate(tenant_params):
        t0 = telem.now_ns() if telem else 0
        logits, _ = nm.adaptive_forward(params, test_lat, L)
        accs.append(float((np.argmax(logits, axis=1) == test_labs).mean()))
        if telem:
            dur = telem.now_ns() - t0
            telem.hists["eval"].record(dur)
            telem.span("fleet.eval", t0, dur, tenant=i)
            telem.count("eval_sweeps")
    return float(np.mean(accs))


def serve(n_tenants, events_per_tenant, frames, seed=7, telem=None):
    train, _test = nm.gen_world(seed, frames)
    ws, head = nm.init_net(seed)
    ws_q = [nm.fq_weight(w) for w in ws]          # calibration oracle
    wq = [nm.quant_weight_codes(w) for w in ws]   # the true-INT8 stage
    init_events = [(c, s, imgs) for (c, s, imgs) in train if c < 4 and s < 2]
    init_imgs = np.concatenate([e[2] for e in init_events]).astype(np.float32) / 255.0
    init_labs = np.concatenate([np.full(len(e[2]), e[0], np.int32) for e in init_events])
    a_max, pooled = nm.calibrate(ws_q, init_imgs[:96])
    init_lat = nm.frozen_int(wq, a_max, init_imgs, L)

    tenants = []
    for t in range(n_tenants):
        rep = nm.Replay(N_LR, FEAT, 8, pooled)
        rep.init_fill(init_lat, init_labs, np.random.RandomState(100 + t))
        tenants.append({"params": nm.init_params(ws, head, L), "rep": rep,
                        "rs": np.random.RandomState(1000 + t), "events": 0})

    # round-robin event stream: (tenant, class, session)
    stream = []
    pool = [(c, s) for c in range(nm.NCLS) for s in range(6) if not (c < 4 and s < 2)]
    for e in range(events_per_tenant):
        for t in range(n_tenants):
            c, s = pool[(t * 7 + e) % len(pool)]
            stream.append((t, c, s))
    frames_of = {(c, s): imgs for (c, s, imgs) in train}

    lat_ms = []
    t0 = time.perf_counter()
    frozen_calls = 0
    for i in range(0, len(stream), COALESCE):
        batch = stream[i:i + COALESCE]
        te0 = time.perf_counter()
        tb0 = telem.now_ns() if telem else 0
        imgs = np.concatenate([frames_of[(c, s)] for (_t, c, s) in batch]).astype(np.float32) / 255.0
        lats = nm.frozen_int(wq, a_max, imgs, L)  # ONE coalesced integer call
        frozen_calls += 1
        if telem:
            telem.span("fleet.coalesce", tb0, telem.now_ns() - tb0, n=len(batch))
            telem.count("frozen_forwards")
            telem.count("frozen_rows", len(imgs))
            telem.count("coalesced_events", len(batch))
        row = 0
        for (t, c, _s) in batch:
            n = frames
            ev_lat, ev_lab = lats[row:row + n], np.full(n, c, np.int32)
            row += n
            ten = tenants[t]
            ten["events"] += 1
            ta0 = telem.now_ns() if telem else 0
            steps = 0
            for _ep in range(2):
                order = ten["rs"].permutation(n)
                for pos in range(0, n - B_NEW + 1, B_NEW):
                    pick = order[pos:pos + B_NEW]
                    r_lat, r_lab = ten["rep"].sample(B_TRAIN - B_NEW, ten["rs"])
                    bl = np.concatenate([ev_lat[pick], r_lat])
                    bb = np.concatenate([ev_lab[pick], r_lab])
                    nm.train_step(ten["params"], bl, bb, 0.1, L)
                    steps += 1
            ten["rep"].event_update(ev_lat, ev_lab, ten["events"], ten["rs"])
            if telem:
                dur = telem.now_ns() - ta0
                telem.span("tenant.apply", ta0, dur, tenant=t)
                telem.hists["serve"].record(dur)
                telem.count("train_steps", steps)
        # charge the whole coalesced batch's wall to each of its events
        # (single-threaded mirror: stage A+B are serial)
        per_ev = (time.perf_counter() - te0) * 1e3 / len(batch)
        lat_ms.extend([per_ev] * len(batch))
        if telem:
            # the mirror's dispatch-path latency: same per-event charge the
            # rust server stamps (submit -> applied), back-dated spans
            ns = int(per_ev * 1e6)
            t_end = telem.now_ns()
            for (t, _c, _s) in batch:
                telem.hists["dispatch"].record(ns)
                telem.span("fleet.dispatch", t_end - ns, ns, tenant=t)
                telem.count("dispatches")
    wall = time.perf_counter() - t0
    lat_ms.sort()
    n = len(lat_ms)
    pick = lambda q: lat_ms[min(max(int(np.ceil(q * n)) - 1, 0), n - 1)]
    mean_acc = eval_mean_accuracy([t["params"] for t in tenants], wq, a_max, _test, telem)
    return {
        "tenants": n_tenants,
        "events": n,
        "events_per_sec": round(n / wall, 3),
        "p50_ms": round(pick(0.50), 3),
        "p99_ms": round(pick(0.99), 3),
        "mean_events_per_frozen_call": round(n / frozen_calls, 3),
    }, round(mean_acc, 3)


# ---- the tiered (disk-spill) serving mirror ------------------------------

def serve_tiered(frames, seed=7, budget=BUDGET):
    """The example's act 5 at mirror fidelity: 2x the nominal tenant
    count under the same budget, coldest tenants spilled to real files
    (pickle stands in for the rust snapshot codec; byte accounting uses
    the EXACT snapshot_bytes of the rust format), lazy restores with
    real disk IO on the serving path, then the eviction + rebalance
    (promote-then-readmit under the watermarks) arithmetic."""
    train, test = nm.gen_world(seed, frames)
    ws, head = nm.init_net(seed)
    ws_q = [nm.fq_weight(w) for w in ws]          # calibration oracle
    wq = [nm.quant_weight_codes(w) for w in ws]   # the true-INT8 stage
    init_events = [(c, s, imgs) for (c, s, imgs) in train if c < 4 and s < 2]
    init_imgs = np.concatenate([e[2] for e in init_events]).astype(np.float32) / 255.0
    init_labs = np.concatenate([np.full(len(e[2]), e[0], np.int32) for e in init_events])
    a_max, pooled = nm.calibrate(ws_q, init_imgs[:96])
    init_lat = nm.frozen_int(wq, a_max, init_imgs, L)
    filled = min(len(init_labs), N_LR)

    overhead = tenant_overhead()
    per8 = overhead + buffer_bytes(N_LR, FEAT, 8)
    nominal = (budget - shared_backbone_bytes()) // per8
    n = nominal * 2
    spills0, demos0, states, in_use, disk = tiered_admissions(n, filled, budget)
    in_use = [in_use]  # boxed for the closures
    disk = [disk]

    def demote_values(rep):
        # the integer 8->7 code remap at value level (rust: remap_code)
        s8, s7 = rep.a_max / 255.0, rep.a_max / 127.0
        q8 = np.rint(rep.lat / max(s8, 1e-12))
        rep.lat = (np.rint(q8 * 127.0 / 255.0) * s7).astype(np.float32)
        rep.bits = 7

    spill_dir = tempfile.mkdtemp(prefix="tinycl_mirror_spill_")
    tenants = {}
    # `unspills` counts EVERY readmission (lazy serve restores + eval
    # maintenance + rebalance), matching the rust governor tally's
    # unspills field; `lazy` is the serve-path subset the report calls
    # lazy_restores
    counters = {"lazy": 0, "spills": 0, "unspills": 0}
    for t in range(n):
        rep = nm.Replay(N_LR, FEAT, 8, pooled)
        rep.init_fill(init_lat, init_labs, np.random.RandomState(100 + t))
        if states[t]["bits"] == 7:
            demote_values(rep)
        obj = {"params": nm.init_params(ws, head, L), "rep": rep,
               "rs": np.random.RandomState(1000 + t), "events": 0}
        if states[t]["resident"]:
            tenants[t] = obj
        else:
            with open(os.path.join(spill_dir, f"tenant_{t}.pkl"), "wb") as f:
                pickle.dump(obj, f)

    def tenant_ram(t):
        return overhead + buffer_bytes(states[t]["slots"], FEAT, states[t]["bits"])

    def spill_coldest():
        i = min(tenants, key=lambda t: (states[t]["clock"], t))
        with open(os.path.join(spill_dir, f"tenant_{i}.pkl"), "wb") as f:
            pickle.dump(tenants.pop(i), f)
        states[i]["resident"] = False
        in_use[0] -= tenant_ram(i)
        disk[0] += snapshot_bytes(states[i]["slots"], FEAT, states[i]["bits"], filled)
        counters["spills"] += 1

    def ensure_resident(t, lazy):
        if t in tenants:
            return
        needed = tenant_ram(t)
        while budget - in_use[0] < needed:
            spill_coldest()   # SpillOnly relief: lossless by construction
        path = os.path.join(spill_dir, f"tenant_{t}.pkl")
        with open(path, "rb") as f:
            tenants[t] = pickle.load(f)
        os.remove(path)
        states[t]["resident"] = True
        in_use[0] += needed
        disk[0] -= snapshot_bytes(states[t]["slots"], FEAT, states[t]["bits"], filled)
        counters["unspills"] += 1
        if lazy:
            counters["lazy"] += 1

    # one NICv2 event per tenant, round-robin, coalesced like serve()
    pool = [(c, s) for c in range(nm.NCLS) for s in range(6) if not (c < 4 and s < 2)]
    stream = [(t,) + pool[(t * 7) % len(pool)] for t in range(n)]
    frames_of = {(c, s): imgs for (c, s, imgs) in train}
    clock = [n]
    lat_ms = []
    t0 = time.perf_counter()
    for i in range(0, len(stream), COALESCE):
        batch = stream[i:i + COALESCE]
        te0 = time.perf_counter()
        imgs = np.concatenate(
            [frames_of[(c, s)] for (_t, c, s) in batch]).astype(np.float32) / 255.0
        lats = nm.frozen_int(wq, a_max, imgs, L)
        row = 0
        for (t, c, _s) in batch:
            ev_lat, ev_lab = lats[row:row + frames], np.full(frames, c, np.int32)
            row += frames
            ensure_resident(t, lazy=True)
            states[t]["clock"] = clock[0]
            clock[0] += 1
            ten = tenants[t]
            ten["events"] += 1
            for _ep in range(2):
                order = ten["rs"].permutation(frames)
                for pos in range(0, frames - B_NEW + 1, B_NEW):
                    pick_ = order[pos:pos + B_NEW]
                    r_lat, r_lab = ten["rep"].sample(B_TRAIN - B_NEW, ten["rs"])
                    nm.train_step(ten["params"], np.concatenate([ev_lat[pick_], r_lat]),
                                  np.concatenate([ev_lab[pick_], r_lab]), 0.1, L)
            ten["rep"].event_update(ev_lat, ev_lab, ten["events"], ten["rs"])
        per_ev = (time.perf_counter() - te0) * 1e3 / len(batch)
        lat_ms.extend([per_ev] * len(batch))
    wall = time.perf_counter() - t0
    lazy_restores = counters["lazy"]

    # mean accuracy over ALL 2x tenants (restores here are maintenance,
    # not lazy-serve restores)
    params_of = []
    for t in range(n):
        ensure_resident(t, lazy=False)
        params_of.append(tenants[t]["params"])
    mean_acc = eval_mean_accuracy(params_of, wq, a_max, test)

    # rebalance mirror: evict residents (keep one warm/Q7 tenant) down
    # below the low watermark, then promote-then-readmit up to the high
    # watermark — governor.rs::plan_boost order
    low, high = int(LOW_WM * budget), int(HIGH_WM * budget)
    warm = [t for t in sorted(tenants) if states[t]["bits"] == 7]
    keep = warm[0] if warm else min(tenants)
    gone = set()
    for t in sorted(tenants):
        if t != keep and in_use[0] >= low:
            del tenants[t]
            states[t]["resident"] = False
            gone.add(t)
            in_use[0] -= tenant_ram(t)
    promoted = unspilled = 0
    if in_use[0] < low:
        for t in sorted(tenants, key=lambda t: (-states[t]["clock"], t)):
            if states[t]["bits"] == 7:
                grow = arena_bytes(states[t]["slots"], FEAT, 8) \
                    - arena_bytes(states[t]["slots"], FEAT, 7)
                if in_use[0] + grow <= high:
                    states[t]["bits"] = 8
                    in_use[0] += grow
                    promoted += 1
        cold = [t for t in range(n) if t not in gone and not states[t]["resident"]]
        for t in sorted(cold, key=lambda t: (-states[t]["clock"], t)):
            b = tenant_ram(t)
            if in_use[0] + b <= high:
                states[t]["resident"] = True
                in_use[0] += b
                disk[0] -= snapshot_bytes(states[t]["slots"], FEAT, states[t]["bits"], filled)
                counters["unspills"] += 1
                unspilled += 1
    for f in os.listdir(spill_dir):
        os.remove(os.path.join(spill_dir, f))
    os.rmdir(spill_dir)

    lat_ms.sort()
    m = len(lat_ms)
    pick = lambda q: lat_ms[min(max(int(np.ceil(q * m)) - 1, 0), m - 1)]
    return {
        "budget_mb": budget // (1024 * 1024),
        "nominal_capacity": int(nominal),
        "tenants_admitted": int(n),
        "capacity_x": round(n / nominal, 3),
        "admission_spills": int(spills0),
        "admission_demotions": int(demos0),
        "lazy_restores": int(lazy_restores),
        "serve_events_per_sec": round(m / wall, 3),
        "p50_ms": round(pick(0.50), 3),
        "p99_ms": round(pick(0.99), 3),
        "mean_tenant_accuracy": round(mean_acc, 3),
        "rebalance_promoted": int(promoted),
        "rebalance_unspilled": int(unspilled),
        "total_spills": int(spills0 + counters["spills"]),
        "total_unspills": int(counters["unspills"]),
    }


# ---- the robustness mirror ----------------------------------------------

def overload_mirror(events=16, queue_depth=2, stall_ms=20.0):
    """Admission control under a stalled worker: the same bounded queue
    driven by a blocking submitter vs a shed(max_wait=0) submitter
    (server.rs run loop, Admission::Block vs Admission::Shed)."""

    def drive(shed):
        q = queue.Queue(maxsize=queue_depth)

        def worker():
            while True:
                item = q.get()
                if item is None:
                    return
                time.sleep(stall_ms / 1e3)  # the injected worker stall

        th = threading.Thread(target=worker)
        th.start()
        waits, rejected = [], 0
        for i in range(events):
            t0 = time.perf_counter()
            if shed:
                try:
                    q.put_nowait(i)
                except queue.Full:
                    rejected += 1  # Rejected::Overloaded + retry-after
            else:
                q.put(i)
            waits.append((time.perf_counter() - t0) * 1e3)
        q.put(None)
        th.join()
        return max(waits), rejected

    blocking_worst, _ = drive(shed=False)
    shed_worst, rejected = drive(shed=True)
    return {
        "events": events,
        "queue_depth": queue_depth,
        "stall_ms": stall_ms,
        "blocking_p_worst_ms": round(blocking_worst, 3),
        "shed_p_worst_ms": round(shed_worst, 3),
        "rejected_events": int(rejected),
    }


def robustness_block(frames, seed=7, stride=4, reps=30):
    """Mirror of the chaos machinery: degraded (strided) eval cost, and
    the spill-retry + quarantine + empty-replay-rebuild recovery path
    (faults.rs RetryPolicy, server.rs degrade_tenant). Returns the
    BENCH robustness object; `recovery` is deterministic, the two
    timing sub-blocks are not."""
    train, test = nm.gen_world(seed, frames)
    ws, head = nm.init_net(seed)
    ws_q = [nm.fq_weight(w) for w in ws]
    wq = [nm.quant_weight_codes(w) for w in ws]
    init_events = [(c, s, imgs) for (c, s, imgs) in train if c < 4 and s < 2]
    init_imgs = np.concatenate([e[2] for e in init_events]).astype(np.float32) / 255.0
    init_labs = np.concatenate([np.full(len(e[2]), e[0], np.int32) for e in init_events])
    a_max, pooled = nm.calibrate(ws_q, init_imgs[:96])
    init_lat = nm.frozen_int(wq, a_max, init_imgs, L)

    def fresh_tenant():
        rep = nm.Replay(N_LR, FEAT, 8, pooled)
        rep.init_fill(init_lat, init_labs, np.random.RandomState(100))
        return {"params": nm.init_params(ws, head, L), "rep": rep}

    # -- degraded eval: full test split vs the EVAL_SAMPLE_STRIDE subset
    params = fresh_tenant()["params"]
    test_imgs = np.concatenate([imgs for (_c, imgs) in test]).astype(np.float32) / 255.0
    test_labs = np.concatenate([np.full(len(imgs), c, np.int32) for (c, imgs) in test])
    lat = nm.frozen_int(wq, a_max, test_imgs, L)

    def timed_eval(latents, labs):
        t0 = time.perf_counter()
        for _ in range(reps):
            logits, _ = nm.adaptive_forward(params, latents, L)
        acc = float((np.argmax(logits, axis=1) == labs).mean())
        return (time.perf_counter() - t0) * 1e3 / reps, acc

    full_ms, full_acc = timed_eval(lat, test_labs)
    sampled_ms, sampled_acc = timed_eval(lat[::stride], test_labs[::stride])
    degraded_eval = {
        "test_rows": int(len(test_labs)),
        "stride": stride,
        "full_ms": round(full_ms, 4),
        "sampled_ms": round(sampled_ms, 4),
        "full_accuracy": round(full_acc, 3),
        "sampled_accuracy": round(sampled_acc, 3),
    }

    # -- recovery: retried spill write, lying-disk corruption discovered
    # by the checksum at restore, quarantine + empty-replay rebuild
    spill_dir = tempfile.mkdtemp(prefix="tinycl_mirror_chaos_")
    path = os.path.join(spill_dir, "tenant_0.pkl")
    payload = pickle.dumps(fresh_tenant())
    io_retries = 0
    for attempt in range(4):  # RetryPolicy::default().attempts
        if attempt < 2:
            io_retries += 1  # injected transient EIO; retry with backoff
            continue
        with open(path, "wb") as f:  # checksummed like snapshot.rs
            f.write(len(payload).to_bytes(8, "little"))
            f.write(zlib.crc32(payload).to_bytes(4, "little"))
            f.write(payload)
        break
    blob = bytearray(open(path, "rb").read())
    blob[12 + len(payload) // 2] ^= 0x40  # one flipped payload byte
    open(path, "wb").write(bytes(blob))

    degrades = tenants_lost = 0
    data = open(path, "rb").read()
    n, crc = int.from_bytes(data[:8], "little"), int.from_bytes(data[8:12], "little")
    body = data[12:12 + n]
    if len(body) != n or zlib.crc32(body) != crc:
        os.rename(path, path + ".quarantine")  # preserved for forensics
        tenant = fresh_tenant()  # empty-replay rebuild: degraded, not lost
        degrades += 1
    else:
        tenant = pickle.loads(body)
        tenants_lost += 1  # undetected corruption would be a real loss
    acc = eval_mean_accuracy([tenant["params"]], wq, a_max, test)
    quarantined = os.path.exists(path + ".quarantine")
    for f in os.listdir(spill_dir):
        os.remove(os.path.join(spill_dir, f))
    os.rmdir(spill_dir)
    recovery = {
        "io_retries": int(io_retries),
        "degrades": int(degrades),
        "tenants_lost": int(tenants_lost),
        "quarantined": bool(quarantined),
        "rebuilt_tenant_accuracy": round(acc, 3),
    }
    return {
        "note": (
            "mirror of rust/src/fleet/faults.rs + the server survival "
            "machinery; the rust chaos suite (rust/tests/chaos.rs, 3 "
            "seeds) asserts the bit-level contracts this block only "
            "sizes. `recovery` is deterministic; the two timing "
            "sub-blocks are not."),
        "overload": overload_mirror(),
        "degraded_eval": degraded_eval,
        "recovery": recovery,
    }


# ---- the async-eval mirror -----------------------------------------------

def async_eval_block(frames, seed=7, n_tenants=4, events_per_tenant=8,
                     sweep_every=2, eval_reps=4):
    """Mirror of FleetServer::evaluate_tenants_async (exec refactor): a
    full test-set eval sweep is launched every `sweep_every` coalesced
    batches, either INLINE on the dispatch thread (the pre-pool
    behaviour — dispatch stalls for the whole sweep) or on a background
    thread standing in for the exec pool's low-priority lane. The
    metric is DISPATCH-PATH throughput — events/s until the last event
    is served, the rust side's `eval_sweep_does_not_block_dispatch`
    property — so inline pays every sweep on the serving clock while
    pooled only pays the CPU contention; the pooled sweeps still run to
    completion (joined, and asserted to produce the same sweep count)
    before the figure is reported. Head params are snapshotted at
    launch in BOTH modes (the rust side locks the tenant slot instead),
    so both modes do identical work."""
    train, test = nm.gen_world(seed, frames)
    ws, head = nm.init_net(seed)
    ws_q = [nm.fq_weight(w) for w in ws]
    wq = [nm.quant_weight_codes(w) for w in ws]
    init_events = [(c, s, imgs) for (c, s, imgs) in train if c < 4 and s < 2]
    init_imgs = np.concatenate([e[2] for e in init_events]).astype(np.float32) / 255.0
    init_labs = np.concatenate([np.full(len(e[2]), e[0], np.int32) for e in init_events])
    a_max, pooled = nm.calibrate(ws_q, init_imgs[:96])
    init_lat = nm.frozen_int(wq, a_max, init_imgs, L)
    test_imgs = np.concatenate([imgs for (_c, imgs) in test]).astype(np.float32) / 255.0
    test_labs = np.concatenate([np.full(len(imgs), c, np.int32) for (c, imgs) in test])
    pool_cs = [(c, s) for c in range(nm.NCLS) for s in range(6) if not (c < 4 and s < 2)]
    frames_of = {(c, s): imgs for (c, s, imgs) in train}

    def sweep(param_snaps):
        # the full-eval cost: the frozen test sweep plus every tenant's
        # head eval, repeated so one sweep rivals several event batches
        # (the rust side's test_latents cache makes repeats cheap; the
        # mirror pays the sweep honestly to give overlap something real)
        for _ in range(eval_reps):
            lat = nm.frozen_int(wq, a_max, test_imgs, L)
            for params in param_snaps:
                logits, _ = nm.adaptive_forward(params, lat, L)
        return float((np.argmax(logits, axis=1) == test_labs).mean())

    def drive(pooled_eval):
        tenants = []
        for t in range(n_tenants):
            rep = nm.Replay(N_LR, FEAT, 8, pooled)
            rep.init_fill(init_lat, init_labs, np.random.RandomState(100 + t))
            tenants.append({"params": nm.init_params(ws, head, L), "rep": rep,
                            "rs": np.random.RandomState(1000 + t), "events": 0})
        stream = []
        for e in range(events_per_tenant):
            for t in range(n_tenants):
                c, s = pool_cs[(t * 7 + e) % len(pool_cs)]
                stream.append((t, c, s))
        accs, threads = [], []
        n_batches = 0
        t0 = time.perf_counter()
        for i in range(0, len(stream), COALESCE):
            batch = stream[i:i + COALESCE]
            imgs = np.concatenate(
                [frames_of[(c, s)] for (_t, c, s) in batch]).astype(np.float32) / 255.0
            lats = nm.frozen_int(wq, a_max, imgs, L)
            row = 0
            for (t, c, _s) in batch:
                ev_lat, ev_lab = lats[row:row + frames], np.full(frames, c, np.int32)
                row += frames
                ten = tenants[t]
                ten["events"] += 1
                for _ep in range(2):
                    order = ten["rs"].permutation(frames)
                    for pos in range(0, frames - B_NEW + 1, B_NEW):
                        pick = order[pos:pos + B_NEW]
                        r_lat, r_lab = ten["rep"].sample(B_TRAIN - B_NEW, ten["rs"])
                        nm.train_step(ten["params"], np.concatenate([ev_lat[pick], r_lat]),
                                      np.concatenate([ev_lab[pick], r_lab]), 0.1, L)
                ten["rep"].event_update(ev_lat, ev_lab, ten["events"], ten["rs"])
            n_batches += 1
            if n_batches % sweep_every == 0:
                snaps = [[p.copy() for p in ten["params"]] for ten in tenants]
                if pooled_eval:
                    th = threading.Thread(target=lambda s=snaps: accs.append(sweep(s)))
                    th.start()
                    threads.append(th)
                else:
                    accs.append(sweep(snaps))
        dispatch_wall = time.perf_counter() - t0  # last event served
        for th in threads:
            th.join()  # the EvalHandle::wait of the mirror
        return len(stream) / dispatch_wall, len(accs)

    eps_inline, sweeps_i = drive(pooled_eval=False)
    eps_pooled, sweeps_p = drive(pooled_eval=True)
    assert sweeps_i == sweeps_p, "mirror: both modes must run the same sweeps"
    return {
        "events": int(n_tenants * events_per_tenant),
        "eval_sweeps": int(sweeps_i),
        "events_per_sec_eval_inline": round(eps_inline, 3),
        "events_per_sec_eval_pooled": round(eps_pooled, 3),
        "speedup": round(eps_pooled / eps_inline, 3),
        "note": (
            "DISPATCH-PATH events/s for the SAME event stream + the SAME completed eval "
            "sweeps; inline = the pre-exec-pool behaviour (dispatch blocks for every "
            "sweep), pooled = sweeps on a background thread mirroring the pool's "
            "low-priority lane, joined (EvalHandle::wait) after the last event and "
            "asserted to complete. The pooled clock still pays the sweeps' CPU "
            "contention on this 2-core host — only the serialization moves off the "
            "serving path, which is exactly the rust-side property "
            "(rust/tests/fleet.rs::eval_sweep_does_not_block_dispatch)."),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=3)
    ap.add_argument("--frames", type=int, default=30)
    args = ap.parse_args()

    grid = []
    accs = {}
    telem = Telem()  # observes the headline 64-tenant grid row only
    for n in (1, 8, 64):
        r, mean_acc = serve(n, args.events, args.frames,
                            telem=telem if n == 64 else None)
        accs[n] = mean_acc
        print(f"tenants {n:3}: {r['events_per_sec']:8.1f} events/s  "
              f"p50 {r['p50_ms']:.1f} ms  p99 {r['p99_ms']:.1f} ms  "
              f"acc {mean_acc:.3f}", flush=True)
        grid.append(r)
    demotions, shrinks, in_use = governed_admissions(64)
    # every committed governor action of the pressured run: 64 admits plus
    # the demote/shrink relief (same count the rust Governor event stream
    # carries, one per GovernorAction)
    telem.count("governor_actions", 64 + demotions + shrinks)
    tier = serve_tiered(args.frames)
    print(f"tiered: {tier['tenants_admitted']} tenants (2x nominal "
          f"{tier['nominal_capacity']}) — {tier['admission_spills']} admission spills, "
          f"{tier['lazy_restores']} lazy restores, {tier['rebalance_promoted']} promotions, "
          f"{tier['serve_events_per_sec']:.1f} events/s, acc "
          f"{tier['mean_tenant_accuracy']:.3f}", flush=True)
    aev = async_eval_block(args.frames)
    print(f"async eval: inline {aev['events_per_sec_eval_inline']:.1f} events/s vs "
          f"pooled {aev['events_per_sec_eval_pooled']:.1f} events/s "
          f"({aev['eval_sweeps']} sweeps, {aev['speedup']:.2f}x)", flush=True)
    robust = robustness_block(args.frames)
    print(f"robustness: shed worst {robust['overload']['shed_p_worst_ms']:.2f} ms vs "
          f"blocking {robust['overload']['blocking_p_worst_ms']:.2f} ms "
          f"({robust['overload']['rejected_events']} rejected); sampled eval "
          f"{robust['degraded_eval']['sampled_ms']:.2f} ms vs full "
          f"{robust['degraded_eval']['full_ms']:.2f} ms; recovery: "
          f"{robust['recovery']['io_retries']} retries, "
          f"{robust['recovery']['degrades']} degrade, "
          f"{robust['recovery']['tenants_lost']} lost", flush=True)
    out = {
        "description": (
            "Fleet serving throughput/latency: N concurrent QLR-CL tenants on one shared "
            "frozen backbone (rust/src/fleet/), events/sec and per-event latency vs tenant "
            "count, the governor outcome of the pressured max-tenant run, and the tiered "
            "(disk-spill) run hosting 2x the nominal capacity under the same budget."),
        "methodology": (
            "tools/fleet_mirror.py — single-threaded numpy mirror of the fleet hot path at "
            "identical sizes (MicroNet-32, l=15, N_LR=4096 UINT-8, 30-frame events, 2 epochs "
            "x 3 steps of batch 64, coalesce 8) on this 2-core container; no rust toolchain "
            "ships in the build image, so these UNDERSTATE the worker-pool rust numbers — "
            "DOUBLY so since the true-INT8 frozen pipeline: numpy has no i8 GEMM, so the "
            "mirror carries the exact integer accumulation in float64 dgemm (slower than the "
            "old f32 sgemm fake-quant mirror), while the rust integer kernels are ~1.5-3x "
            "FASTER than their f32 path (BENCH_kernels.json §int8). "
            "Governor/spill byte arithmetic (incl. snapshot sizes) replayed exactly from "
            "rust/src/fleet/{governor,snapshot}.rs; spill/restore uses real disk IO. "
            "async_eval mirrors FleetServer::evaluate_tenants_async: identical streams + "
            "sweeps with eval inline vs on a background thread (the pool's low lane). "
            "The telemetry block mirrors rust/src/telemetry/: identical log2-bucket "
            "histograms + nearest-rank percentiles (hist.rs) over the 64-tenant row's "
            "dispatch/serve/eval paths, with the span stream exported as Chrome "
            "trace_event JSON (BENCH_fleet.trace.json). "
            "`cargo run --release --example fleet_serving` regenerates authoritative numbers "
            "(and asserts N=1 parity, >=1 demotion, >=1 spill, >=1 lazy restore, >=1 "
            "promotion); `cargo bench --bench fleet` writes results/bench_fleet.tsv. NOTE "
            "the rust example's small (CI) profile uses a 5 MB budget and a 1/4/16 grid, so "
            "the bench-regression guard only matches the tenants=1 row and the tiered "
            "events/sec across profiles."),
        "profile": "full (mirror)",
        "grid": grid,
        "governed_max_run": {
            "budget_mb": 64,
            "tenants_admitted": 64,
            "demotions_8_to_7": demotions,
            "shrinks": shrinks,
            "bytes_in_use_mb": round(in_use / (1024 * 1024), 3),
            "mean_tenant_accuracy": accs[64],
            "n1_parity_accuracy": accs[1],
            "note": ("governor arithmetic replayed exactly from "
                     "rust/src/fleet/governor.rs; bit-exact parity/round-trip claims are "
                     "asserted by the rust example and tests, not mirrored here"),
        },
        "tiered_run": tier,
        "async_eval": aev,
        "robustness": robust,
        "telemetry": telem.block({"shed": 0, "io_retries": 0, "degrades": 0}),
        "determinism": {
            "note": ("regenerated (and compared across two same-seed runs) by the CI "
                     "determinism job; mirror values are placeholders with the same keys"),
            "n1_parity_accuracy": accs[1],
            "governed_admits": 64,
            "governed_demotions": demotions,
            "governed_mean_accuracy": accs[64],
            "grid_events": [r["events"] for r in grid],
            "tiered_nominal": tier["nominal_capacity"],
            "tiered_admitted": tier["tenants_admitted"],
            "tiered_admission_spills": tier["admission_spills"],
            "tiered_admission_demotions": tier["admission_demotions"],
            "tiered_events": tier["tenants_admitted"],
            "tiered_mean_accuracy": tier["mean_tenant_accuracy"],
            "robustness_recovery": robust["recovery"],
        },
    }
    with open("BENCH_fleet.json", "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    with open("BENCH_fleet.trace.json", "w") as f:
        json.dump(telem.chrome_trace(), f)
        f.write("\n")
    td = out["telemetry"]
    print(f"telemetry: {td['events_recorded']} spans, dispatch p99 "
          f"{td['dispatch']['p99_ms']:.1f} ms, serve p99 {td['serve']['p99_ms']:.1f} ms "
          f"— wrote BENCH_fleet.trace.json")
    print(f"governed 64-tenant run: {demotions} demotions, {shrinks} shrinks, "
          f"{in_use / 1048576:.1f} MiB in use — wrote BENCH_fleet.json")


if __name__ == "__main__":
    main()
