#!/usr/bin/env python3
"""CI guard over the BENCH_*.json measurement files.

Three modes, all stdlib-only:

  validate FILE
      Schema check: the keys every downstream consumer (EXPERIMENTS.md,
      the determinism job, this very guard) relies on must exist with
      sane types/ranges. Catches a half-written or hand-mangled bench
      file before it lands.

  validate-kernels FILE
      Schema + floor check for BENCH_kernels.json: the matmul/replay
      sections, the true-INT8 section, and the `pool` spawn-overhead
      record (pooled small-GEMM must be >= the scoped-spawn baseline,
      bit-identical). Frozen-forward before/after
      cases hard-fail below 1.0x (a genuine inversion: the integer path
      slower than the oracle) and WARN below the 1.5x target — the
      shared measurement host swings from ~1x under load to ~1.9x when
      quiet, so a single honest regeneration can land well under the
      target without a real regression (the committed record is a
      median over 6 runs; regenerate the same way, on a quiet host).
      The recorded PER-LAYER parity must say <= 1 LSB.

  regress --baseline OLD --new NEW [--max-regression 0.20]
      Throughput guard: fail if any matched events/sec figure in NEW
      dropped more than the threshold below OLD (the committed
      baseline). Latency-only drift does not fail (CI runners are
      noisy); throughput collapsing by >20% is the "someone serialized
      the hot path" signal this exists to catch.

  diff A B
      Determinism guard: the `determinism` object of two same-seed runs
      must be byte-for-byte equal (it holds only scheduling-independent
      quantities: admission outcomes, event counts, accuracies, the N=1
      parity figure). Any difference is a reproducibility regression.

Exit code 0 on pass, 1 on failure (with a per-key report on stderr).
"""

import argparse
import json
import sys


def fail(msg):
    print(f"bench_check: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: cannot load: {e}")


GRID_ROW_KEYS = ("tenants", "events", "events_per_sec", "p50_ms", "p99_ms")
ASYNC_EVAL_KEYS = (
    "events",
    "eval_sweeps",
    "events_per_sec_eval_inline",
    "events_per_sec_eval_pooled",
    "speedup",
)
GOVERNED_KEYS = (
    "budget_mb",
    "tenants_admitted",
    "demotions_8_to_7",
    "mean_tenant_accuracy",
    "n1_parity_accuracy",
)
TIERED_KEYS = (
    "budget_mb",
    "nominal_capacity",
    "tenants_admitted",
    "capacity_x",
    "admission_spills",
    "lazy_restores",
    "rebalance_promoted",
    "mean_tenant_accuracy",
)


def validate(path):
    doc = load(path)
    problems = []
    for key in ("description", "methodology", "profile", "grid", "governed_max_run"):
        if key not in doc:
            problems.append(f"missing top-level key '{key}'")
    for i, row in enumerate(doc.get("grid", [])):
        for key in GRID_ROW_KEYS:
            if key not in row:
                problems.append(f"grid[{i}] missing '{key}'")
        if row.get("events_per_sec", 1) <= 0:
            problems.append(f"grid[{i}].events_per_sec not positive")
    gov = doc.get("governed_max_run", {})
    for key in GOVERNED_KEYS:
        if key not in gov:
            problems.append(f"governed_max_run missing '{key}'")
    if not 0.0 <= gov.get("n1_parity_accuracy", 0.0) <= 1.0:
        problems.append("governed_max_run.n1_parity_accuracy out of [0, 1]")
    tier = doc.get("tiered_run")
    if tier is None:
        problems.append("missing 'tiered_run' (the spill-tier capacity record)")
    else:
        for key in TIERED_KEYS:
            if key not in tier:
                problems.append(f"tiered_run missing '{key}'")
        if tier.get("capacity_x", 0) < 2.0:
            problems.append(
                f"tiered_run.capacity_x = {tier.get('capacity_x')} < 2.0 "
                "(the spill tier must at least double capacity)"
            )
        if tier.get("lazy_restores", 0) < 1:
            problems.append("tiered_run.lazy_restores < 1")
        if tier.get("rebalance_promoted", 0) < 1:
            problems.append("tiered_run.rebalance_promoted < 1")
    if "determinism" not in doc:
        problems.append("missing 'determinism' (the same-seed diff subset)")
    ae = doc.get("async_eval")
    if ae is None:
        problems.append("missing 'async_eval' (inline vs pooled eval record)")
    else:
        for key in ASYNC_EVAL_KEYS:
            if key not in ae:
                problems.append(f"async_eval missing '{key}'")
        inline = ae.get("events_per_sec_eval_inline", 0.0)
        pooled = ae.get("events_per_sec_eval_pooled", 0.0)
        if pooled <= 0 or inline <= 0:
            problems.append("async_eval throughput figures must be positive")
        elif pooled < inline:
            problems.append(
                f"async_eval: pooled eval throughput {pooled} < inline "
                f"{inline} — moving eval off the serving path made "
                "serving SLOWER"
            )
    if problems:
        fail(f"{path}:\n  " + "\n  ".join(problems))
    print(f"bench_check: {path}: schema OK "
          f"({len(doc.get('grid', []))} grid rows, profile {doc.get('profile')!r})")


OVERLOAD_KEYS = ("blocking_p_worst_ms", "shed_p_worst_ms", "rejected_events")
DEGRADED_EVAL_KEYS = ("test_rows", "stride", "full_ms", "sampled_ms")
RECOVERY_KEYS = ("io_retries", "degrades", "tenants_lost", "quarantined")


def validate_fleet(path):
    """Robustness floors over BENCH_fleet.json's `robustness` block: shed
    admission must beat blocking worst-case, sampled eval must beat full
    eval, and the recovery drill must retry, quarantine and degrade
    without losing a tenant."""
    doc = load(path)
    rb = doc.get("robustness")
    if rb is None:
        fail(f"{path}: missing 'robustness' "
             "(regenerate with tools/fleet_mirror.py)")
    problems = []
    ov = rb.get("overload", {})
    for key in OVERLOAD_KEYS:
        if key not in ov:
            problems.append(f"robustness.overload missing '{key}'")
    if ov.get("rejected_events", 0) < 1:
        problems.append("robustness.overload.rejected_events < 1 "
                        "(shed admission never fired)")
    shed_ms = ov.get("shed_p_worst_ms", float("inf"))
    block_ms = ov.get("blocking_p_worst_ms", 0.0)
    if shed_ms > block_ms:
        problems.append(
            f"robustness.overload: shed worst-case {shed_ms} ms exceeds "
            f"blocking worst-case {block_ms} ms — shedding must bound "
            "submitter latency, that is its whole point"
        )
    ev = rb.get("degraded_eval", {})
    for key in DEGRADED_EVAL_KEYS:
        if key not in ev:
            problems.append(f"robustness.degraded_eval missing '{key}'")
    if ev.get("sampled_ms", float("inf")) >= ev.get("full_ms", 0.0):
        problems.append(
            f"robustness.degraded_eval: sampled {ev.get('sampled_ms')} ms "
            f">= full {ev.get('full_ms')} ms — the degraded rung saved "
            "nothing"
        )
    rec = rb.get("recovery", {})
    for key in RECOVERY_KEYS:
        if key not in rec:
            problems.append(f"robustness.recovery missing '{key}'")
    if rec.get("tenants_lost", 1) != 0:
        problems.append(f"robustness.recovery.tenants_lost = "
                        f"{rec.get('tenants_lost')} (must be 0)")
    if rec.get("degrades", 0) < 1:
        problems.append("robustness.recovery.degrades < 1 "
                        "(corruption was never exercised)")
    if rec.get("io_retries", 0) < 1:
        problems.append("robustness.recovery.io_retries < 1 "
                        "(the retry path was never exercised)")
    if not rec.get("quarantined", False):
        problems.append("robustness.recovery.quarantined is false "
                        "(damaged snapshots must be preserved)")
    if problems:
        fail(f"{path}:\n  " + "\n  ".join(problems))
    print(f"bench_check: {path}: robustness floors OK "
          f"(shed {shed_ms} ms <= blocking {block_ms} ms, "
          f"{ov.get('rejected_events')} rejected, sampled eval "
          f"{ev.get('sampled_ms')} ms < full {ev.get('full_ms')} ms, "
          f"0 tenants lost)")


INT8_KEYS = (
    "gemm_i8_512cubed_1thread_gmac_per_s",
    "speedup_vs_f32_blocked_1thread",
    "frozen_forward_cases",
    "parity",
)
POOL_KEYS = (
    "small_gemm_shape",
    "scoped_spawn_us_per_call",
    "pooled_us_per_call",
    "pooled_over_scoped",
    "bit_identical",
)


def validate_kernels(path):
    doc = load(path)
    problems = []
    for key in ("description", "methodology", "matmul", "replay", "int8"):
        if key not in doc:
            problems.append(f"missing top-level key '{key}'")
    int8 = doc.get("int8", {})
    for key in INT8_KEYS:
        if key not in int8:
            problems.append(f"int8 missing '{key}'")
    if int8.get("speedup_vs_f32_blocked_1thread", 0) < 1.0:
        problems.append("int8 GEMM core slower than the f32 engine")
    cases = int8.get("frozen_forward_cases", [])
    if not cases:
        problems.append("int8.frozen_forward_cases is empty")
    warned = 0
    for i, case in enumerate(cases):
        for key in ("case", "fakequant_ms", "int8_ms", "speedup"):
            if key not in case:
                problems.append(f"frozen_forward_cases[{i}] missing '{key}'")
        speedup = case.get("speedup", 0)
        if speedup < 1.0:
            problems.append(
                f"frozen_forward_cases[{i}] ({case.get('case')}): speedup "
                f"{speedup} < 1.0x — the integer path is SLOWER than the oracle"
            )
        elif speedup < 1.5:
            warned += 1
            print(
                f"bench_check: WARN: frozen_forward_cases[{i}] "
                f"({case.get('case')}): speedup {speedup} below the 1.5x "
                "target — noisy host? take the median of several runs",
                file=sys.stderr,
            )
    parity = int8.get("parity", {})
    if parity.get("per_layer_max_code_diff", 99) > 1:
        problems.append("int8.parity.per_layer_max_code_diff > 1 LSB")
    pool = doc.get("pool")
    if pool is None:
        problems.append("missing 'pool' (persistent-pool spawn-overhead record)")
    else:
        for key in POOL_KEYS:
            if key not in pool:
                problems.append(f"pool missing '{key}'")
        ratio = pool.get("pooled_over_scoped", 0)
        # the spawn-overhead floor: a persistent pool must never lose to
        # per-call thread spawning on the small-GEMM shape where spawn
        # cost dominates — below 1.0 the pool's whole premise is broken
        if ratio < 1.0:
            problems.append(
                f"pool.pooled_over_scoped = {ratio} < 1.0 — pooled "
                "small-GEMM throughput fell below the scoped-spawn baseline"
            )
        if pool.get("bit_identical") is not True:
            problems.append("pool.bit_identical is not true (pooled result "
                            "diverged from the spawned one)")
    if problems:
        fail(f"{path}:\n  " + "\n  ".join(problems))
    print(f"bench_check: {path}: kernels schema OK "
          f"({len(cases)} frozen-forward cases, {len(cases) - warned} at >= 1.5x, "
          f"{warned} warned, pool ratio {doc['pool']['pooled_over_scoped']}x)")


def throughput_figures(doc):
    """(label, higher-is-better figure) pairs comparable across runs —
    fleet events/sec, or the kernel file's GMAC/s + int8 speedups."""
    out = {}
    for row in doc.get("grid", []):
        out[f"grid[tenants={row.get('tenants')}]"] = row.get("events_per_sec")
    tier = doc.get("tiered_run") or {}
    if "serve_events_per_sec" in tier:
        out["tiered_run"] = tier["serve_events_per_sec"]
    int8 = doc.get("int8") or {}
    if "gemm_i8_512cubed_1thread_gmac_per_s" in int8:
        out["int8.gemm_1thread_gmac_per_s"] = int8["gemm_i8_512cubed_1thread_gmac_per_s"]
    for case in int8.get("frozen_forward_cases", []):
        out[f"int8.frozen[{case.get('case')}].speedup"] = case.get("speedup")
    return out


def regress(baseline_path, new_path, max_regression):
    base = throughput_figures(load(baseline_path))
    new = throughput_figures(load(new_path))
    compared, failures = 0, []
    for label, old_eps in base.items():
        new_eps = new.get(label)
        if old_eps is None or new_eps is None or old_eps <= 0:
            continue
        compared += 1
        floor = old_eps * (1.0 - max_regression)
        verdict = "ok" if new_eps >= floor else "REGRESSED"
        print(
            f"bench_check: {label}: {old_eps:.2f} -> {new_eps:.2f} events/s "
            f"(floor {floor:.2f}) {verdict}"
        )
        if new_eps < floor:
            failures.append(label)
    if compared == 0:
        fail("no comparable throughput figures between baseline and new file")
    if failures:
        fail(
            f"throughput regressed >{max_regression:.0%} vs the committed baseline: "
            + ", ".join(failures)
        )
    print(f"bench_check: throughput within {max_regression:.0%} of baseline "
          f"({compared} figures compared)")


def diff_determinism(path_a, path_b):
    a, b = load(path_a), load(path_b)
    det_a, det_b = a.get("determinism"), b.get("determinism")
    if det_a is None or det_b is None:
        fail("one of the runs has no 'determinism' object")
    if det_a == det_b:
        print(f"bench_check: determinism subsets identical across runs "
              f"({len(det_a)} keys)")
        return
    keys = sorted(set(det_a) | set(det_b))
    lines = []
    for key in keys:
        va, vb = det_a.get(key, "<missing>"), det_b.get(key, "<missing>")
        if va != vb:
            lines.append(f"{key}: {va!r} != {vb!r}")
    fail("same-seed runs disagree on scheduling-independent outcomes:\n  "
         + "\n  ".join(lines))


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="mode", required=True)
    v = sub.add_parser("validate", help="schema-check BENCH_fleet.json")
    v.add_argument("file")
    vk = sub.add_parser(
        "validate-kernels",
        help="schema + 1.5x-floor check for BENCH_kernels.json",
    )
    vk.add_argument("file")
    vf = sub.add_parser(
        "validate-fleet",
        help="robustness floors (overload/degraded-eval/recovery) for BENCH_fleet.json",
    )
    vf.add_argument("file")
    r = sub.add_parser("regress", help="fail on >threshold throughput drop")
    r.add_argument("--baseline", required=True)
    r.add_argument("--new", required=True, dest="new_file")
    r.add_argument("--max-regression", type=float, default=0.20)
    d = sub.add_parser("diff", help="compare the determinism subset of two runs")
    d.add_argument("a")
    d.add_argument("b")
    args = ap.parse_args()
    if args.mode == "validate":
        validate(args.file)
    elif args.mode == "validate-kernels":
        validate_kernels(args.file)
    elif args.mode == "validate-fleet":
        validate_fleet(args.file)
    elif args.mode == "regress":
        regress(args.baseline, args.new_file, args.max_regression)
    else:
        diff_determinism(args.a, args.b)


if __name__ == "__main__":
    main()
