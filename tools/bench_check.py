#!/usr/bin/env python3
"""CI guard over the BENCH_*.json measurement files.

Three modes, all stdlib-only:

  validate FILE
      Schema check: the keys every downstream consumer (EXPERIMENTS.md,
      the determinism job, this very guard) relies on must exist with
      sane types/ranges. Catches a half-written or hand-mangled bench
      file before it lands.

  validate-kernels FILE
      Schema + floor check for BENCH_kernels.json: the matmul/replay
      sections, the true-INT8 section, and the `pool` spawn-overhead
      record (pooled small-GEMM must be >= the scoped-spawn baseline,
      bit-identical). Frozen-forward before/after
      cases hard-fail below 1.0x (a genuine inversion: the integer path
      slower than the oracle) and WARN below the 1.5x target — the
      shared measurement host swings from ~1x under load to ~1.9x when
      quiet, so a single honest regeneration can land well under the
      target without a real regression (the committed record is a
      median over 6 runs; regenerate the same way, on a quiet host).
      The recorded PER-LAYER parity must say <= 1 LSB.

  validate-telemetry FILE [--trace TRACE]
      Telemetry floors over BENCH_fleet.json's `telemetry` block: the
      dispatch/serve latency histograms must be real measurements
      (n >= 1, 0 < p50 <= p95 <= p99 <= max) and the SLO counters
      coherent. With --trace, also schema-checks the Chrome trace
      artifact: every event well-formed, phases limited to the emitted
      vocabulary, per-thread timestamps monotonic, and begin/end spans
      balanced per thread.

  validate-shard FILE [--min-migrations 1] [--min-shards 2]
                 [--min-net-retries N] [--min-failovers N]
                 [--max-mttr-ms MS]
      Sharded-serving floors over a `tinycl shard-client --out` record:
      the loopback run must have >= --min-shards shards, >= 1 live
      migration, tenants_lost == 0, and a determinism.acc_bits block of
      16-hex-digit f64 bit patterns. The same file's `determinism`
      object feeds the `diff` mode below: a 2-shard run and a 1-shard
      control with the same seeds must produce byte-identical blocks.
      The recovery flags gate the partition-tolerance `recovery` block
      (chaos runs / crash drills): retries actually injected, at least
      one supervisor failover, restart MTTR under the ceiling, and
      recovery.tenants_lost == 0 whenever the block is present.

  regress --baseline OLD --new NEW [--max-regression 0.20]
      Throughput guard: fail if any matched events/sec figure in NEW
      dropped more than the threshold below OLD (the committed
      baseline). Latency-only drift does not fail (CI runners are
      noisy); throughput collapsing by >20% is the "someone serialized
      the hot path" signal this exists to catch. The one latency guard:
      telemetry dispatch p99 may not blow past the baseline by more
      than --max-p99-blowup (default 3.0x) — generous enough for runner
      noise, tight enough to catch "someone put a lock on the dispatch
      path".

  diff A B
      Determinism guard: the `determinism` object of two same-seed runs
      must be byte-for-byte equal (it holds only scheduling-independent
      quantities: admission outcomes, event counts, accuracies, the N=1
      parity figure). Any difference is a reproducibility regression.

Exit code 0 on pass, 1 on failure (with a per-key report on stderr).
"""

import argparse
import json
import sys


def fail(msg):
    print(f"bench_check: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: cannot load: {e}")


GRID_ROW_KEYS = ("tenants", "events", "events_per_sec", "p50_ms", "p99_ms")
ASYNC_EVAL_KEYS = (
    "events",
    "eval_sweeps",
    "events_per_sec_eval_inline",
    "events_per_sec_eval_pooled",
    "speedup",
)
GOVERNED_KEYS = (
    "budget_mb",
    "tenants_admitted",
    "demotions_8_to_7",
    "mean_tenant_accuracy",
    "n1_parity_accuracy",
)
TIERED_KEYS = (
    "budget_mb",
    "nominal_capacity",
    "tenants_admitted",
    "capacity_x",
    "admission_spills",
    "lazy_restores",
    "rebalance_promoted",
    "mean_tenant_accuracy",
)


def validate(path):
    doc = load(path)
    problems = []
    for key in ("description", "methodology", "profile", "grid", "governed_max_run"):
        if key not in doc:
            problems.append(f"missing top-level key '{key}'")
    for i, row in enumerate(doc.get("grid", [])):
        for key in GRID_ROW_KEYS:
            if key not in row:
                problems.append(f"grid[{i}] missing '{key}'")
        if row.get("events_per_sec", 1) <= 0:
            problems.append(f"grid[{i}].events_per_sec not positive")
    gov = doc.get("governed_max_run", {})
    for key in GOVERNED_KEYS:
        if key not in gov:
            problems.append(f"governed_max_run missing '{key}'")
    if not 0.0 <= gov.get("n1_parity_accuracy", 0.0) <= 1.0:
        problems.append("governed_max_run.n1_parity_accuracy out of [0, 1]")
    tier = doc.get("tiered_run")
    if tier is None:
        problems.append("missing 'tiered_run' (the spill-tier capacity record)")
    else:
        for key in TIERED_KEYS:
            if key not in tier:
                problems.append(f"tiered_run missing '{key}'")
        if tier.get("capacity_x", 0) < 2.0:
            problems.append(
                f"tiered_run.capacity_x = {tier.get('capacity_x')} < 2.0 "
                "(the spill tier must at least double capacity)"
            )
        if tier.get("lazy_restores", 0) < 1:
            problems.append("tiered_run.lazy_restores < 1")
        if tier.get("rebalance_promoted", 0) < 1:
            problems.append("tiered_run.rebalance_promoted < 1")
    if "determinism" not in doc:
        problems.append("missing 'determinism' (the same-seed diff subset)")
    ae = doc.get("async_eval")
    if ae is None:
        problems.append("missing 'async_eval' (inline vs pooled eval record)")
    else:
        for key in ASYNC_EVAL_KEYS:
            if key not in ae:
                problems.append(f"async_eval missing '{key}'")
        inline = ae.get("events_per_sec_eval_inline", 0.0)
        pooled = ae.get("events_per_sec_eval_pooled", 0.0)
        if pooled <= 0 or inline <= 0:
            problems.append("async_eval throughput figures must be positive")
        elif pooled < inline:
            problems.append(
                f"async_eval: pooled eval throughput {pooled} < inline "
                f"{inline} — moving eval off the serving path made "
                "serving SLOWER"
            )
    if problems:
        fail(f"{path}:\n  " + "\n  ".join(problems))
    print(f"bench_check: {path}: schema OK "
          f"({len(doc.get('grid', []))} grid rows, profile {doc.get('profile')!r})")


OVERLOAD_KEYS = ("blocking_p_worst_ms", "shed_p_worst_ms", "rejected_events")
DEGRADED_EVAL_KEYS = ("test_rows", "stride", "full_ms", "sampled_ms")
RECOVERY_KEYS = ("io_retries", "degrades", "tenants_lost", "quarantined")


def validate_fleet(path):
    """Robustness floors over BENCH_fleet.json's `robustness` block: shed
    admission must beat blocking worst-case, sampled eval must beat full
    eval, and the recovery drill must retry, quarantine and degrade
    without losing a tenant."""
    doc = load(path)
    rb = doc.get("robustness")
    if rb is None:
        fail(f"{path}: missing 'robustness' "
             "(regenerate with tools/fleet_mirror.py)")
    problems = []
    ov = rb.get("overload", {})
    for key in OVERLOAD_KEYS:
        if key not in ov:
            problems.append(f"robustness.overload missing '{key}'")
    if ov.get("rejected_events", 0) < 1:
        problems.append("robustness.overload.rejected_events < 1 "
                        "(shed admission never fired)")
    shed_ms = ov.get("shed_p_worst_ms", float("inf"))
    block_ms = ov.get("blocking_p_worst_ms", 0.0)
    if shed_ms > block_ms:
        problems.append(
            f"robustness.overload: shed worst-case {shed_ms} ms exceeds "
            f"blocking worst-case {block_ms} ms — shedding must bound "
            "submitter latency, that is its whole point"
        )
    ev = rb.get("degraded_eval", {})
    for key in DEGRADED_EVAL_KEYS:
        if key not in ev:
            problems.append(f"robustness.degraded_eval missing '{key}'")
    if ev.get("sampled_ms", float("inf")) >= ev.get("full_ms", 0.0):
        problems.append(
            f"robustness.degraded_eval: sampled {ev.get('sampled_ms')} ms "
            f">= full {ev.get('full_ms')} ms — the degraded rung saved "
            "nothing"
        )
    rec = rb.get("recovery", {})
    for key in RECOVERY_KEYS:
        if key not in rec:
            problems.append(f"robustness.recovery missing '{key}'")
    if rec.get("tenants_lost", 1) != 0:
        problems.append(f"robustness.recovery.tenants_lost = "
                        f"{rec.get('tenants_lost')} (must be 0)")
    if rec.get("degrades", 0) < 1:
        problems.append("robustness.recovery.degrades < 1 "
                        "(corruption was never exercised)")
    if rec.get("io_retries", 0) < 1:
        problems.append("robustness.recovery.io_retries < 1 "
                        "(the retry path was never exercised)")
    if not rec.get("quarantined", False):
        problems.append("robustness.recovery.quarantined is false "
                        "(damaged snapshots must be preserved)")
    if problems:
        fail(f"{path}:\n  " + "\n  ".join(problems))
    print(f"bench_check: {path}: robustness floors OK "
          f"(shed {shed_ms} ms <= blocking {block_ms} ms, "
          f"{ov.get('rejected_events')} rejected, sampled eval "
          f"{ev.get('sampled_ms')} ms < full {ev.get('full_ms')} ms, "
          f"0 tenants lost)")


SHARD_KEYS = (
    "shards",
    "tenants",
    "events_per_tenant",
    "events",
    "events_per_sec",
    "sheds",
    "migrations",
    "tenants_lost",
)


def validate_shard(path, min_migrations=1, min_shards=2,
                   min_net_retries=0, min_failovers=0, max_mttr_ms=None):
    """Floors over a `tinycl shard-client --out` record: the loopback run
    must have actually sharded (>= min_shards), performed at least one
    live migration, lost no tenant, and carried the bit-exact accuracy
    block the cross-shard-count `diff` mode compares.

    With any of --min-net-retries / --min-failovers / --max-mttr-ms the
    record must also carry the partition-tolerance `recovery` block (a
    chaos run that injected nothing proved nothing): retries actually
    happened, the supervisor actually restarted a shard, MTTR stayed
    under the ceiling, and the drill lost no tenant. Records from
    fault-free runs may omit the block as long as no floor asks for it."""
    doc = load(path)
    problems = []
    if doc.get("bench") != "shard":
        problems.append(f"bench != 'shard' (got {doc.get('bench')!r})")
    for key in SHARD_KEYS:
        if key not in doc:
            problems.append(f"missing '{key}'")
    if doc.get("shards", 0) < min_shards:
        problems.append(f"shards = {doc.get('shards')} < {min_shards}")
    if doc.get("migrations", 0) < min_migrations:
        problems.append(
            f"migrations = {doc.get('migrations')} < {min_migrations} "
            "(no live migration happened — the drill's whole point)"
        )
    if doc.get("tenants_lost", 1) != 0:
        problems.append(f"tenants_lost = {doc.get('tenants_lost')} (must be 0)")
    if doc.get("events_per_sec", 0) <= 0:
        problems.append("events_per_sec not positive")
    if doc.get("events", 0) < doc.get("tenants", 1):
        problems.append("fewer events than tenants — the run barely ran")
    det = doc.get("determinism")
    if not isinstance(det, dict) or not isinstance(det.get("acc_bits"), dict):
        problems.append("missing 'determinism.acc_bits' (per-tenant accuracy "
                        "bit patterns — the cross-shard-count parity record)")
    else:
        acc = det["acc_bits"]
        if len(acc) != doc.get("tenants"):
            problems.append(
                f"determinism.acc_bits has {len(acc)} tenants, run had "
                f"{doc.get('tenants')}"
            )
        for t, bits in acc.items():
            if not (isinstance(bits, str) and len(bits) == 16):
                problems.append(f"determinism.acc_bits[{t}] not a 16-hex-digit "
                                f"f64 bit pattern: {bits!r}")
    wants_recovery = min_net_retries > 0 or min_failovers > 0 \
        or max_mttr_ms is not None
    rec = doc.get("recovery")
    if rec is None:
        if wants_recovery:
            problems.append("missing 'recovery' block (recovery floors were "
                            "requested; rerun with a fault plan / crash drill)")
    elif not isinstance(rec, dict):
        problems.append(f"'recovery' is not an object: {rec!r}")
    else:
        # rust's shard-client keeps tenants_lost top-level only; the
        # mirror duplicates it into the block — either spelling must be 0
        if rec.get("tenants_lost", doc.get("tenants_lost", 1)) != 0:
            problems.append(f"recovery.tenants_lost = "
                            f"{rec.get('tenants_lost', doc.get('tenants_lost'))}"
                            " (must be 0)")
        if rec.get("pending_unresolved", 0) != 0:
            problems.append(f"recovery.pending_unresolved = "
                            f"{rec.get('pending_unresolved')} (every migration "
                            "outcome must be committed or rolled back)")
        if rec.get("net_retries", 0) < min_net_retries:
            problems.append(
                f"recovery.net_retries = {rec.get('net_retries')} < "
                f"{min_net_retries} (the fault plan injected nothing)")
        if rec.get("failovers", 0) < min_failovers:
            problems.append(
                f"recovery.failovers = {rec.get('failovers')} < "
                f"{min_failovers} (no shard was ever failed over)")
        if max_mttr_ms is not None:
            mttrs = rec.get("mttr_ms")
            if not isinstance(mttrs, list):
                mttrs = [mttrs] if isinstance(mttrs, (int, float)) else []
            if not mttrs:
                problems.append("recovery.mttr_ms absent but --max-mttr-ms "
                                "was requested (no restart was measured)")
            for m in mttrs:
                if m > max_mttr_ms:
                    problems.append(f"recovery.mttr_ms {m} > ceiling "
                                    f"{max_mttr_ms}")
    if problems:
        fail(f"{path}:\n  " + "\n  ".join(problems))
    extra = ""
    if rec:
        extra = (f", recovery: {rec.get('net_retries', 0)} retries / "
                 f"{rec.get('failovers', 0)} failovers / "
                 f"{rec.get('duplicates', 0)} duplicate acks")
    print(f"bench_check: {path}: shard floors OK "
          f"({doc['shards']} shards, {doc['tenants']} tenants, "
          f"{doc['migrations']} migrations, 0 lost, "
          f"{doc['events_per_sec']:.1f} events/s{extra})")


TELEMETRY_HIST_KEYS = ("n", "p50_ms", "p95_ms", "p99_ms", "max_ms")
# the phase vocabulary our exporter emits: complete events, counter
# samples, instant markers, metadata — plus B/E accepted for tools that
# re-emit begin/end pairs from the same data
TRACE_PHASES = ("X", "B", "E", "C", "i", "I", "M")


def validate_telemetry(path, trace_path=None):
    """Floors over the `telemetry` block (exact log2-histogram
    percentiles of the recorded governed run) and, optionally, schema
    checks over the committed Chrome trace artifact."""
    doc = load(path)
    tel = doc.get("telemetry")
    if tel is None:
        fail(f"{path}: missing 'telemetry' "
             "(regenerate with tools/fleet_mirror.py or the example)")
    problems = []
    for key in ("events_recorded", "events_dropped", "counters", "robustness"):
        if key not in tel:
            problems.append(f"telemetry missing '{key}'")
    if tel.get("events_recorded", 0) < 1:
        problems.append("telemetry.events_recorded < 1 (nothing was traced)")
    for hist_name in ("dispatch", "serve"):
        h = tel.get(hist_name)
        if h is None:
            problems.append(f"telemetry missing '{hist_name}' histogram")
            continue
        for key in TELEMETRY_HIST_KEYS:
            if key not in h:
                problems.append(f"telemetry.{hist_name} missing '{key}'")
        if h.get("n", 0) < 1:
            problems.append(f"telemetry.{hist_name}.n < 1 (no samples recorded)")
        p50, p95 = h.get("p50_ms", 0.0), h.get("p95_ms", 0.0)
        p99, pmax = h.get("p99_ms", 0.0), h.get("max_ms", 0.0)
        # the p99 floor: the SLO figure must be a real, ordered
        # measurement — a zero p99 means the histogram never saw a sample
        if not 0.0 < p50 <= p95 <= p99 <= pmax:
            problems.append(
                f"telemetry.{hist_name}: percentiles not ordered/positive "
                f"(p50 {p50}, p95 {p95}, p99 {p99}, max {pmax})"
            )
    counters = tel.get("counters", {})
    if counters.get("dispatches", 0) < 1:
        problems.append("telemetry.counters.dispatches < 1")
    if counters.get("governor_actions", 0) < 1:
        problems.append("telemetry.counters.governor_actions < 1 "
                        "(the governed run must commit actions)")
    if problems:
        fail(f"{path}:\n  " + "\n  ".join(problems))
    d = tel["dispatch"]
    print(f"bench_check: {path}: telemetry floors OK "
          f"(dispatch n={d['n']} p50={d['p50_ms']} ms p99={d['p99_ms']} ms, "
          f"{tel['events_recorded']} events traced)")
    if trace_path is not None:
        validate_trace(trace_path)


def validate_trace(path):
    doc = load(path)
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        fail(f"{path}: 'traceEvents' missing or empty")
    problems = []
    last_ts = {}     # tid -> latest begin/complete timestamp seen
    open_spans = {}  # tid -> stack of open B names
    n_spans = 0
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            problems.append(f"traceEvents[{i}] is not an object")
            continue
        ph = ev.get("ph")
        if ph not in TRACE_PHASES:
            problems.append(f"traceEvents[{i}]: unknown phase {ph!r}")
            continue
        if ph == "M":
            if ev.get("name") != "thread_name":
                problems.append(f"traceEvents[{i}]: metadata other than thread_name")
            continue
        for key in ("name", "ts", "pid", "tid"):
            if key not in ev:
                problems.append(f"traceEvents[{i}]: missing '{key}'")
        tid = ev.get("tid")
        ts = ev.get("ts", 0.0)
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"traceEvents[{i}]: bad ts {ts!r}")
            continue
        if ph in ("X", "B"):
            n_spans += 1
            if ts < last_ts.get(tid, float("-inf")):
                problems.append(
                    f"traceEvents[{i}]: ts {ts} went backwards on tid {tid} "
                    f"(last {last_ts[tid]}) — per-thread order violated"
                )
            last_ts[tid] = ts
        if ph == "X" and ev.get("dur", -1) < 0:
            problems.append(f"traceEvents[{i}]: complete event without dur >= 0")
        if ph == "B":
            open_spans.setdefault(tid, []).append(ev.get("name"))
        if ph == "E":
            stack = open_spans.get(tid, [])
            if not stack:
                problems.append(f"traceEvents[{i}]: E without matching B on tid {tid}")
            else:
                stack.pop()
    for tid, stack in open_spans.items():
        if stack:
            problems.append(f"tid {tid}: {len(stack)} B span(s) never closed: {stack}")
    if n_spans == 0:
        problems.append("no span events (X/B) at all")
    if problems:
        fail(f"{path}:\n  " + "\n  ".join(problems[:40]))
    print(f"bench_check: {path}: trace OK "
          f"({n_spans} spans on {len(last_ts)} threads, balanced, monotonic)")


INT8_KEYS = (
    "gemm_i8_512cubed_1thread_gmac_per_s",
    "speedup_vs_f32_blocked_1thread",
    "frozen_forward_cases",
    "parity",
)
POOL_KEYS = (
    "small_gemm_shape",
    "scoped_spawn_us_per_call",
    "pooled_us_per_call",
    "pooled_over_scoped",
    "bit_identical",
)


def validate_kernels(path):
    doc = load(path)
    problems = []
    for key in ("description", "methodology", "matmul", "replay", "int8"):
        if key not in doc:
            problems.append(f"missing top-level key '{key}'")
    int8 = doc.get("int8", {})
    for key in INT8_KEYS:
        if key not in int8:
            problems.append(f"int8 missing '{key}'")
    if int8.get("speedup_vs_f32_blocked_1thread", 0) < 1.0:
        problems.append("int8 GEMM core slower than the f32 engine")
    cases = int8.get("frozen_forward_cases", [])
    if not cases:
        problems.append("int8.frozen_forward_cases is empty")
    warned = 0
    for i, case in enumerate(cases):
        for key in ("case", "fakequant_ms", "int8_ms", "speedup"):
            if key not in case:
                problems.append(f"frozen_forward_cases[{i}] missing '{key}'")
        speedup = case.get("speedup", 0)
        if speedup < 1.0:
            problems.append(
                f"frozen_forward_cases[{i}] ({case.get('case')}): speedup "
                f"{speedup} < 1.0x — the integer path is SLOWER than the oracle"
            )
        elif speedup < 1.5:
            warned += 1
            print(
                f"bench_check: WARN: frozen_forward_cases[{i}] "
                f"({case.get('case')}): speedup {speedup} below the 1.5x "
                "target — noisy host? take the median of several runs",
                file=sys.stderr,
            )
    parity = int8.get("parity", {})
    if parity.get("per_layer_max_code_diff", 99) > 1:
        problems.append("int8.parity.per_layer_max_code_diff > 1 LSB")
    pool = doc.get("pool")
    if pool is None:
        problems.append("missing 'pool' (persistent-pool spawn-overhead record)")
    else:
        for key in POOL_KEYS:
            if key not in pool:
                problems.append(f"pool missing '{key}'")
        ratio = pool.get("pooled_over_scoped", 0)
        # the spawn-overhead floor: a persistent pool must never lose to
        # per-call thread spawning on the small-GEMM shape where spawn
        # cost dominates — below 1.0 the pool's whole premise is broken
        if ratio < 1.0:
            problems.append(
                f"pool.pooled_over_scoped = {ratio} < 1.0 — pooled "
                "small-GEMM throughput fell below the scoped-spawn baseline"
            )
        if pool.get("bit_identical") is not True:
            problems.append("pool.bit_identical is not true (pooled result "
                            "diverged from the spawned one)")
    if problems:
        fail(f"{path}:\n  " + "\n  ".join(problems))
    print(f"bench_check: {path}: kernels schema OK "
          f"({len(cases)} frozen-forward cases, {len(cases) - warned} at >= 1.5x, "
          f"{warned} warned, pool ratio {doc['pool']['pooled_over_scoped']}x)")


def throughput_figures(doc):
    """(label, higher-is-better figure) pairs comparable across runs —
    fleet events/sec, or the kernel file's GMAC/s + int8 speedups."""
    out = {}
    for row in doc.get("grid", []):
        out[f"grid[tenants={row.get('tenants')}]"] = row.get("events_per_sec")
    tier = doc.get("tiered_run") or {}
    if "serve_events_per_sec" in tier:
        out["tiered_run"] = tier["serve_events_per_sec"]
    int8 = doc.get("int8") or {}
    if "gemm_i8_512cubed_1thread_gmac_per_s" in int8:
        out["int8.gemm_1thread_gmac_per_s"] = int8["gemm_i8_512cubed_1thread_gmac_per_s"]
    for case in int8.get("frozen_forward_cases", []):
        out[f"int8.frozen[{case.get('case')}].speedup"] = case.get("speedup")
    return out


def regress(baseline_path, new_path, max_regression, max_p99_blowup=3.0):
    base_doc, new_doc = load(baseline_path), load(new_path)
    base = throughput_figures(base_doc)
    new = throughput_figures(new_doc)
    compared, failures = 0, []
    for label, old_eps in base.items():
        new_eps = new.get(label)
        if old_eps is None or new_eps is None or old_eps <= 0:
            continue
        compared += 1
        floor = old_eps * (1.0 - max_regression)
        verdict = "ok" if new_eps >= floor else "REGRESSED"
        print(
            f"bench_check: {label}: {old_eps:.2f} -> {new_eps:.2f} events/s "
            f"(floor {floor:.2f}) {verdict}"
        )
        if new_eps < floor:
            failures.append(label)
    # the one latency figure guarded: telemetry dispatch p99 (the SLO
    # number). Threshold is multiplicative and generous — runner noise
    # moves p99 by 2x, a lock on the dispatch path moves it by 10x.
    old_p99 = ((base_doc.get("telemetry") or {}).get("dispatch") or {}).get("p99_ms")
    new_p99 = ((new_doc.get("telemetry") or {}).get("dispatch") or {}).get("p99_ms")
    if old_p99 and new_p99 and old_p99 > 0:
        compared += 1
        ceiling = old_p99 * max_p99_blowup
        verdict = "ok" if new_p99 <= ceiling else "REGRESSED"
        print(
            f"bench_check: telemetry.dispatch.p99_ms: {old_p99} -> {new_p99} "
            f"(ceiling {ceiling:.3f}) {verdict}"
        )
        if new_p99 > ceiling:
            failures.append("telemetry.dispatch.p99_ms")
    if compared == 0:
        fail("no comparable throughput figures between baseline and new file")
    if failures:
        fail(
            f"throughput regressed >{max_regression:.0%} vs the committed baseline: "
            + ", ".join(failures)
        )
    print(f"bench_check: throughput within {max_regression:.0%} of baseline "
          f"({compared} figures compared)")


def diff_determinism(path_a, path_b):
    a, b = load(path_a), load(path_b)
    det_a, det_b = a.get("determinism"), b.get("determinism")
    if det_a is None or det_b is None:
        fail("one of the runs has no 'determinism' object")
    if det_a == det_b:
        print(f"bench_check: determinism subsets identical across runs "
              f"({len(det_a)} keys)")
        return
    keys = sorted(set(det_a) | set(det_b))
    lines = []
    for key in keys:
        va, vb = det_a.get(key, "<missing>"), det_b.get(key, "<missing>")
        if va != vb:
            lines.append(f"{key}: {va!r} != {vb!r}")
    fail("same-seed runs disagree on scheduling-independent outcomes:\n  "
         + "\n  ".join(lines))


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="mode", required=True)
    v = sub.add_parser("validate", help="schema-check BENCH_fleet.json")
    v.add_argument("file")
    vk = sub.add_parser(
        "validate-kernels",
        help="schema + 1.5x-floor check for BENCH_kernels.json",
    )
    vk.add_argument("file")
    vf = sub.add_parser(
        "validate-fleet",
        help="robustness floors (overload/degraded-eval/recovery) for BENCH_fleet.json",
    )
    vf.add_argument("file")
    vs = sub.add_parser(
        "validate-shard",
        help="sharded-serving floors (>=1 migration, 0 lost, acc-bit block) "
             "for a `tinycl shard-client --out` record",
    )
    vs.add_argument("file")
    vs.add_argument("--min-migrations", type=int, default=1)
    vs.add_argument("--min-shards", type=int, default=2)
    vs.add_argument("--min-net-retries", type=int, default=0,
                    help="require recovery.net_retries >= N (chaos floor)")
    vs.add_argument("--min-failovers", type=int, default=0,
                    help="require recovery.failovers >= N (crash drill floor)")
    vs.add_argument("--max-mttr-ms", type=float, default=None,
                    help="ceiling on recovery.mttr_ms restart times")
    vt = sub.add_parser(
        "validate-telemetry",
        help="telemetry p99 floors + Chrome-trace schema for BENCH_fleet.json",
    )
    vt.add_argument("file")
    vt.add_argument("--trace", default=None,
                    help="also schema-check this Chrome trace artifact")
    r = sub.add_parser("regress", help="fail on >threshold throughput drop")
    r.add_argument("--baseline", required=True)
    r.add_argument("--new", required=True, dest="new_file")
    r.add_argument("--max-regression", type=float, default=0.20)
    r.add_argument("--max-p99-blowup", type=float, default=3.0)
    d = sub.add_parser("diff", help="compare the determinism subset of two runs")
    d.add_argument("a")
    d.add_argument("b")
    args = ap.parse_args()
    if args.mode == "validate":
        validate(args.file)
    elif args.mode == "validate-kernels":
        validate_kernels(args.file)
    elif args.mode == "validate-fleet":
        validate_fleet(args.file)
    elif args.mode == "validate-shard":
        validate_shard(args.file, args.min_migrations, args.min_shards,
                       args.min_net_retries, args.min_failovers,
                       args.max_mttr_ms)
    elif args.mode == "validate-telemetry":
        validate_telemetry(args.file, args.trace)
    elif args.mode == "regress":
        regress(args.baseline, args.new_file, args.max_regression, args.max_p99_blowup)
    else:
        diff_determinism(args.a, args.b)


if __name__ == "__main__":
    main()
