#!/usr/bin/env python3
"""Generate the golden tenant-snapshot fixture.

This is an independent Python mirror of ``rust/src/fleet/snapshot.rs``'s
``encode()`` (over the ``net::wire`` little-endian codec). The emitted
file, ``tools/fixtures/snapshot_v1.bin``, is committed; the Rust test
``golden_fixture_decodes_and_reencodes_identically`` (rust/tests/
snapshot.rs) decodes it, checks every field, and re-encodes it back to
the identical bytes. That pins the byte format: any accidental layout
change breaks the test, and a deliberate change must bump
SNAPSHOT_VERSION and regenerate the fixture with this script.

Usage: python3 tools/make_snapshot_fixture.py [out_path]
"""

import struct
import sys
from pathlib import Path

MAGIC = b"TCSN"
VERSION = 1


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x00000100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


class W:
    def __init__(self):
        self.buf = bytearray()

    def u8(self, v):
        self.buf += struct.pack("<B", v)

    def u32(self, v):
        self.buf += struct.pack("<I", v)

    def u64(self, v):
        self.buf += struct.pack("<Q", v)

    def i32(self, v):
        self.buf += struct.pack("<i", v)

    def f32(self, v):
        self.buf += struct.pack("<f", v)

    def f64(self, v):
        self.buf += struct.pack("<d", v)

    def s(self, text):
        raw = text.encode("utf-8")
        self.u32(len(raw))
        self.buf += raw


# ---- the fixture tenant (all values asserted by the Rust test) --------------

CFG = dict(l=15, n_lr=4, lr_bits=8, int8_frozen=1, lr=0.1, epochs=2, seed=42)
NEXT_SEQ = 3
METRICS = dict(
    events=3, steps=6, train_seen=96, train_correct=60, last_loss=0.5,
    demotions=0, shrinks=0, promotions=1, spills=2,
)
RNG_STATE = [1, 2, 3, 4]
# sorted by name, matching ParamState's canonical ordering
PARAMS = [
    ("head.b", [3], [0.5, -1.25, 3.75]),
    ("head.w", [2, 3], [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
]
CAPACITY = 4
LATENT_ELEMS = 8
BITS = 8
A_MAX = 1.25
ARENA = bytes(range(CAPACITY * LATENT_ELEMS * BITS // 8))  # 32 bytes
LABELS = [0, 1, 2, -1]  # slot 3 empty
FILLED = [0, 1, 2]
PARKED = [
    (3, [7], [0.25] * (1 * LATENT_ELEMS)),
    (5, [8, 9], [0.5] * (2 * LATENT_ELEMS)),
]


def payload() -> bytes:
    w = W()
    # config
    w.u32(CFG["l"])
    w.u64(CFG["n_lr"])
    w.u8(CFG["lr_bits"])
    w.u8(CFG["int8_frozen"])
    w.f32(CFG["lr"])
    w.u64(CFG["epochs"])
    w.u64(CFG["seed"])
    # sequence position
    w.u64(NEXT_SEQ)
    # metrics
    w.u64(METRICS["events"])
    w.u64(METRICS["steps"])
    w.u64(METRICS["train_seen"])
    w.u64(METRICS["train_correct"])
    w.f64(METRICS["last_loss"])
    w.u32(METRICS["demotions"])
    w.u32(METRICS["shrinks"])
    w.u32(METRICS["promotions"])
    w.u32(METRICS["spills"])
    # rng stream position
    for word in RNG_STATE:
        w.u64(word)
    # adaptive params
    w.u32(len(PARAMS))
    for name, shape, data in PARAMS:
        w.s(name)
        w.u8(len(shape))
        for d in shape:
            w.u32(d)
        w.u64(len(data))
        for v in data:
            w.f32(v)
    # replay memory (packed mode)
    w.u64(CAPACITY)
    w.u64(LATENT_ELEMS)
    w.u8(0)
    w.u8(BITS)
    w.f32(A_MAX)
    w.u64(len(ARENA))
    w.buf += ARENA
    for lab in LABELS:
        w.i32(lab)
    w.u64(len(FILLED))
    for s in FILLED:
        w.u32(s)
    # parked events
    w.u64(len(PARKED))
    for seq, labs, lats in PARKED:
        w.u64(seq)
        w.u64(len(labs))
        for lab in labs:
            w.i32(lab)
        for v in lats:
            w.f32(v)
    return bytes(w.buf)


def main():
    out = Path(sys.argv[1]) if len(sys.argv) > 1 else (
        Path(__file__).resolve().parent / "fixtures" / "snapshot_v1.bin"
    )
    body = payload()
    blob = (
        MAGIC
        + struct.pack("<I", VERSION)
        + struct.pack("<Q", len(body))
        + struct.pack("<Q", fnv1a64(body))
        + body
    )
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_bytes(blob)
    print(f"wrote {out} ({len(blob)} bytes, payload {len(body)}, "
          f"fnv1a64 {fnv1a64(body):016x})")


if __name__ == "__main__":
    main()
