//! End-to-end driver (the DESIGN.md §4 "e2e" experiment): run the FULL
//! NICv2-mini continual-learning protocol on Core50-mini through the
//! entire stack — frozen INT-8 stage, quantized replay memory,
//! adaptive-stage training — logging the accuracy curve, the per-event
//! losses, and the *simulated VEGA latency/energy* each event would cost
//! on the paper's hardware.
//!
//! Runs on the default backend: PJRT when `artifacts/` exists, otherwise
//! the native kernel engine over the deterministic synthetic Core50-mini
//! (zero artifacts, zero XLA — the fully offline path).
//!
//!     cargo run --release --example continual_learning_e2e [events] [seed]
//!
//! Results land in results/e2e_curve.tsv and are summarized on stdout
//! (EXPERIMENTS.md records a reference run).

use anyhow::Result;
use tinycl::coordinator::{run_protocol, CLConfig, RunOptions};
use tinycl::models::micronet32;
use tinycl::runtime::open_default_backend;
use tinycl::simulator::executor::{event_seconds, EventSpec};
use tinycl::simulator::targets::vega;
use tinycl::util::table::Table;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_events: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(0);
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1);

    let (be, ds) = open_default_backend()?;
    println!("backend: {}", be.platform());
    let cfg = CLConfig {
        l: 13,
        n_lr: 256,
        lr_bits: 8,
        int8_frozen: true,
        lr: 0.1,
        epochs: 2,
        seed,
    };
    let opts = RunOptions { eval_every: 4, max_events, verbose: true };

    println!("=== QLR-CL end-to-end: {} ===", cfg.label());
    let result = run_protocol(&*be, &ds, cfg, opts)?;

    // simulated on-target cost of the same per-event workload (VEGA),
    // scaled to the mini model: a mini event = 60 new images, 2 epochs x
    // 7 iterations of batch 64
    let v = vega();
    let net = micronet32();
    let ev = EventSpec { batch: 64, iters: 14, new_images: 60 };
    let vega_event_s = event_seconds(&v, &v.default_hw, &net, cfg.l, &ev);
    let vega_event_j = v.energy_j(vega_event_s);

    let mut t = Table::new(
        "e2e accuracy curve",
        &["event", "test accuracy", "simulated VEGA latency [s]", "simulated VEGA energy [J]"],
    );
    for (ev_idx, acc) in result.accuracy_curve() {
        t.row(vec![
            ev_idx.to_string(),
            format!("{acc:.4}"),
            format!("{:.3}", vega_event_s * ev_idx as f64),
            format!("{:.3}", vega_event_j * ev_idx as f64),
        ]);
    }
    t.print();
    t.save_tsv("results", "e2e_curve")?;

    let losses: Vec<f64> = result.events.iter().map(|e| e.mean_loss).collect();
    println!("\nsummary");
    println!("  events            : {}", result.events.len());
    println!("  accuracy          : {:.3} -> {:.3}", result.initial_acc, result.final_acc);
    println!("  worst forgetting  : {:.3}", result.worst_drop());
    println!(
        "  first/last loss   : {:.3} / {:.3}",
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0)
    );
    println!(
        "  LR memory         : {} bytes ({}-bit packed)",
        result.lr_storage_bytes, cfg.lr_bits
    );
    println!("  host wall/event   : {:?}", result.mean_event_wall());
    println!("  simulated VEGA    : {vega_event_s:.3} s, {vega_event_j:.3} J per event");
    println!("\ncurve written to results/e2e_curve.tsv");

    anyhow::ensure!(
        result.final_acc > result.initial_acc,
        "end-to-end run failed to learn (acc {:.3} -> {:.3})",
        result.initial_acc,
        result.final_acc
    );
    println!("continual_learning_e2e OK");
    Ok(())
}
