//! Hardware design-space exploration (the paper's §V-C methodology as a
//! tool): sweep #cores x L1 size x DMA bandwidth on the VEGA model and
//! report training throughput + the cheapest configuration that reaches
//! the 8-core plateau — the analysis behind the paper's claim that
//! "128 kB of L1 suffices as long as the DMA provides 64 bit/cyc".
//!
//!     cargo run --release --example hw_design_space [--l 20]

use anyhow::Result;
use tinycl::models::mobilenet_v1_128;
use tinycl::simulator::executor::adaptive_macs_per_cyc;
use tinycl::simulator::targets::{vega, HwConfig};
use tinycl::util::cli;
use tinycl::util::table::{fmt, Table};

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&raw, &[]);
    let l = args.usize_or("l", 20);

    let v = vega();
    let net = mobilenet_v1_128();
    let mut t = Table::new(
        &format!(
            "design space: training MAC/cyc, adaptive stage from layer {l} (batch 128, \
             half-duplex DMA)"
        ),
        &["cores", "L1 kB", "bw 8", "bw 16", "bw 32", "bw 64", "bw 128"],
    );

    let mut best: Option<(f64, String)> = None;
    let plateau = {
        let hw = HwConfig {
            cores: 8,
            l1_bytes: 512 * 1024,
            dma_read_bits_per_cyc: 128.0,
            dma_write_bits_per_cyc: 128.0,
            full_duplex: false,
        };
        adaptive_macs_per_cyc(&v, &hw, &net, l, 128)
    };

    for cores in [1usize, 2, 4, 8] {
        for l1 in [64usize, 128, 256, 512] {
            let mut cells = vec![cores.to_string(), l1.to_string()];
            for bw in [8.0, 16.0, 32.0, 64.0, 128.0] {
                let hw = HwConfig {
                    cores,
                    l1_bytes: l1 * 1024,
                    dma_read_bits_per_cyc: bw,
                    dma_write_bits_per_cyc: bw,
                    full_duplex: false,
                };
                let r = adaptive_macs_per_cyc(&v, &hw, &net, l, 128);
                cells.push(fmt(r, 3));
                if r >= 0.93 * plateau {
                    // "cost": L1 kB dominates silicon, then bandwidth wiring
                    let cost = l1 as f64 + bw * 0.5 + cores as f64 * 4.0;
                    let label = format!("{cores} cores, {l1} kB L1, {bw} bit/cyc");
                    if best.as_ref().map(|(c, _)| cost < *c).unwrap_or(true) {
                        best = Some((cost, label));
                    }
                }
            }
            t.row(cells);
        }
    }
    t.print();
    t.save_tsv("results", "hw_design_space")?;

    println!("\nplateau throughput : {plateau:.3} MAC/cyc");
    match best {
        Some((_, label)) => println!("cheapest ~plateau  : {label}"),
        None => println!("no configuration reached 93% of the plateau"),
    }
    println!(
        "(VEGA ships 8 cores, 128 kB L1, 64 bit/cyc full duplex — on the knee, as the paper \
         argues.)"
    );
    Ok(())
}
