//! Fleet serving, end to end and fully offline: 64+ synthetic CL tenants
//! on one shared frozen backbone under a 64 MB memory governor.
//!
//!     cargo run --release --example fleet_serving [small|full] [workers]
//!
//! What it proves (and asserts):
//!
//! 1. **N=1 parity** — a fleet of one tenant reproduces the classic
//!    `run_protocol` single-session accuracy EXACTLY (the engine is
//!    bit-deterministic per row and the tenant shares the session's
//!    training loop + RNG stream);
//! 2. **dense multi-tenancy under budget** — `full`: 64 tenants whose
//!    nominal footprints exceed 64 MB are all admitted because the
//!    governor demotes cold tenants' replay memories 8→7-bit in place
//!    (and shrinks slots past that); at least one demotion is asserted;
//! 3. **cross-tenant batching** — frozen-forward work coalesces across
//!    tenants (mean events per engine call is reported), and batched
//!    inference spans tenants in one grouped engine call;
//! 4. **throughput/latency** — events/sec and p50/p99 per tenant-count,
//!    written to `BENCH_fleet.json` (and echoed on stdout);
//! 5. **the tiered replay hierarchy** — with a spill directory
//!    configured, the SAME RAM budget hosts ≥ 2x the nominal tenant
//!    capacity: coldest tenants spill to checksummed disk snapshots,
//!    restore lazily on their next event (sequence parking preserved),
//!    and once pressure clears `rebalance()` re-widens demoted replay
//!    memories 7→8-bit under the watermark hysteresis. At least one
//!    spill, one lazy restore, and one 7→8-bit promotion are asserted.
//!
//! `small` (the CI profile) runs the same story at 16 tenants on the
//! tiny synthetic world with a 5 MB budget.
//!
//! Threading: `[workers]` sets the number of pool-resident serving
//! tasks; all actual threads come from the ONE persistent exec pool
//! (sized by `TINYCL_THREADS`, logged at startup). Every asserted
//! outcome is independent of both knobs — the CI determinism job
//! re-runs this example at pool widths 1 and 4 and byte-diffs the
//! scheduling-independent subset of `BENCH_fleet.json`.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};
use tinycl::coordinator::{run_protocol, CLConfig, RunOptions};
use tinycl::fleet::{
    traffic, FleetConfig, FleetReport, FleetServer, GovernorAction, InferRequest, TenantConfig,
};
use tinycl::runtime::{open_shared_synthetic, Dataset, SharedBackend};
use tinycl::runtime::synthetic::SyntheticSpec;
use tinycl::telemetry::Telemetry;
use tinycl::util::json::Json;

struct Profile {
    name: &'static str,
    spec: SyntheticSpec,
    tenants: usize,
    n_lr: usize,
    budget_bytes: usize,
    events_per_tenant: usize,
    grid: Vec<usize>,
}

fn profile(name: &str) -> Profile {
    match name {
        "small" => Profile {
            name: "small",
            spec: SyntheticSpec::tiny(),
            tenants: 16,
            n_lr: 1024,
            // sized so ~13 of 16 tenants fit raw: admissions past that
            // exercise the governor's demote/shrink path
            budget_bytes: 5 * 1024 * 1024,
            events_per_tenant: 2,
            grid: vec![1, 4, 16],
        },
        _ => Profile {
            name: "full",
            spec: SyntheticSpec::default(),
            tenants: 64,
            n_lr: 4096,
            // the paper envelope: 64 x (~1.1 MB nominal) does NOT fit —
            // the governor must demote to admit the whole fleet
            budget_bytes: 64 * 1024 * 1024,
            events_per_tenant: 3,
            grid: vec![1, 8, 64],
        },
    }
}

const SPLIT: usize = 15; // head-only adaptive stage (grouped inference path)

/// Build a fleet of `n` tenants and drive `events_per_tenant` NICv2
/// events each (round-robin interleaved). Returns the server + report +
/// tenant ids.
fn serve_fleet(
    be: &SharedBackend,
    ds: &Dataset,
    p: &Profile,
    n: usize,
    budget: usize,
    workers: usize,
    telemetry: bool,
) -> Result<(FleetServer, FleetReport, Vec<usize>)> {
    let mut b = FleetConfig::builder(SPLIT).budget_bytes(budget).max_tenants(n.max(64));
    if telemetry {
        // recorded run: spans + histograms + SLO counters; every
        // asserted outcome is identical with this off (see
        // rust/tests/telemetry.rs for the byte-diff proof)
        b = b.telemetry(Telemetry::enabled());
    }
    let server = FleetServer::new(be.clone(), b.build()?)?;
    let (init_images, init_labels) = traffic::init_pool(ds);
    let init_latents = server.embed_images(&init_images)?;
    let mut ids = Vec::with_capacity(n);
    for t in 0..n {
        let tcfg = TenantConfig { n_lr: p.n_lr, seed: 100 + t as u64, ..TenantConfig::default() };
        ids.push(server.admit_prepared(tcfg, &init_latents, &init_labels)?);
    }
    let seeded: Vec<(usize, u64)> = ids.iter().map(|&id| (id, 100 + id as u64)).collect();
    let events =
        traffic::interleaved_nicv2(&be.manifest().protocol, ds, &seeded, p.events_per_tenant);
    let n_events = events.len();
    let report = server.run(events, workers)?;
    ensure!(report.dropped == 0, "events dropped during serving");
    ensure!(report.events as usize == n_events, "not all events were applied");
    Ok((server, report, ids))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = profile(args.first().map(String::as_str).unwrap_or("full"));
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let (be, ds) = open_shared_synthetic(&p.spec)?;
    println!("== fleet_serving ({} profile) on {} ==", p.name, be.platform());

    // ---- 1. N=1 parity vs the single-session path ----------------------
    let parity_events = p.events_per_tenant.max(2);
    let cl = CLConfig {
        l: SPLIT,
        n_lr: p.n_lr,
        lr_bits: 8,
        int8_frozen: true,
        lr: 0.1,
        epochs: 2,
        seed: 100, // == fleet tenant 0's seed
    };
    let solo = run_protocol(
        &*be,
        &ds,
        cl,
        RunOptions { eval_every: 0, max_events: parity_events, verbose: false },
    )?;
    let one_cfg = FleetConfig::builder(SPLIT).max_tenants(4).build()?;
    let one = FleetServer::new(be.clone(), one_cfg)?;
    let (init_images, init_labels) = traffic::init_pool(&ds);
    let t0 = one.admit(
        TenantConfig { n_lr: p.n_lr, seed: 100, ..TenantConfig::default() },
        &init_images,
        &init_labels,
    )?;
    // the very schedule run_protocol derives from this seed
    let evs =
        traffic::interleaved_nicv2(&be.manifest().protocol, &ds, &[(t0, cl.seed)], parity_events);
    one.run(evs, workers)?;
    let fleet_acc = one.evaluate_tenant(&ds, t0)?;
    println!(
        "N=1 parity: fleet {:.6} vs single-session {:.6} after {parity_events} events",
        fleet_acc, solo.final_acc
    );
    ensure!(
        fleet_acc == solo.final_acc,
        "fleet N=1 diverged from the single-session path: {fleet_acc} != {}",
        solo.final_acc
    );

    // ---- 2+3+4. the tenant-count grid; the biggest run carries the
    //      governor-pressure assertions -------------------------------
    let mut grid_rows: Vec<(usize, FleetReport)> = Vec::new();
    let mut main_run: Option<(FleetServer, Vec<usize>)> = None;
    for &n in &p.grid {
        let last = n == *p.grid.last().unwrap();
        let budget = if last { p.budget_bytes } else { tinycl::fleet::DEFAULT_BUDGET_BYTES };
        // the governed max run is the recorded one: its dispatch/serve
        // percentiles land in BENCH_fleet.json's telemetry block
        let (server, report, ids) = serve_fleet(&be, &ds, &p, n, budget, workers, last)?;
        println!(
            "tenants {n:3}: {:7.1} events/s  p50 {:7.2} ms  p99 {:7.2} ms  \
             ({:.2} events/frozen-call)",
            report.events_per_sec, report.latency.p50_ms, report.latency.p99_ms,
            report.mean_coalesce
        );
        let r = &report.robustness;
        if r.shed + r.io_retries + r.degrades > 0 {
            println!(
                "             robustness: {} shed, {} I/O retries, {} degrades",
                r.shed, r.io_retries, r.degrades
            );
        }
        grid_rows.push((n, report));
        if last {
            main_run = Some((server, ids));
        }
    }
    let (server, ids) = main_run.expect("grid is never empty");
    let main_tm = grid_rows.last().and_then(|(_, r)| r.telemetry.clone());
    if let Some(tr) = &main_tm {
        print!("{}", tr.render());
    }

    // governor must have demoted under the pressured budget
    let tally = server.governor_tally();
    let (admits, demotes, shrinks, rejects) =
        (tally.admits, tally.demotes, tally.shrinks, tally.rejects);
    println!(
        "governor @ {} tenants / {} MB: {admits} admits, {demotes} demotions, \
         {shrinks} shrinks, {rejects} rejects; {:.1} MB in use",
        ids.len(),
        p.budget_bytes / (1024 * 1024),
        server.bytes_in_use() as f64 / (1024.0 * 1024.0)
    );
    for a in server.governor_log() {
        if let GovernorAction::Demote { tenant, from_bits, to_bits, freed } = a {
            println!("  demote tenant {tenant:3}: Q{from_bits} -> Q{to_bits} ({freed} B freed)");
        }
    }
    ensure!(admits == ids.len(), "some tenants were rejected");
    ensure!(rejects == 0, "governor rejected admissions under a feasible budget");
    ensure!(demotes >= 1, "expected at least one 8->7-bit demotion under this budget");
    ensure!(
        server.bytes_in_use() <= p.budget_bytes,
        "governor budget violated: {} > {}",
        server.bytes_in_use(),
        p.budget_bytes
    );

    // per-tenant accuracy: everyone must have learned something. The
    // whole-fleet sweep runs as low-priority tasks on the shared exec
    // pool (async-eval API); on this quiesced server the result is
    // bit-identical to sequential evaluate_tenant calls, and the
    // determinism job diffs the accuracies it produces across runs
    let accs = server.evaluate_tenants_async(&ds, &ids)?.wait()?;
    let mean_acc = accs.iter().sum::<f64>() / accs.len() as f64;
    let min_acc = accs.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("tenant accuracy: mean {mean_acc:.3}, min {min_acc:.3}");
    ensure!(mean_acc > 0.11, "fleet failed to learn (mean acc {mean_acc:.3})");

    // cross-session batched inference: one frozen call + one grouped
    // head call spanning every tenant
    let img = ds.image_elems();
    let probe_rows = 4.min(ds.n_test());
    let mut probe = vec![0f32; probe_rows * img];
    for r in 0..probe_rows {
        ds.test_image_into(r, &mut probe[r * img..(r + 1) * img]);
    }
    let reqs: Vec<InferRequest> =
        ids.iter().map(|&id| InferRequest { tenant: id, images: &probe }).collect();
    let t_inf = std::time::Instant::now();
    let logits = server.infer_batch(&reqs)?;
    let inf_ms = t_inf.elapsed().as_secs_f64() * 1e3;
    ensure!(logits.len() == ids.len());
    ensure!(logits.iter().all(|l| l.len() == probe_rows * be.manifest().num_classes));
    println!(
        "batched inference: {} tenants x {probe_rows} images in {:.2} ms (one grouped call)",
        ids.len(),
        inf_ms
    );

    // snapshot -> evict -> restore keeps the learned state
    let keep = ids[0];
    let acc_before = server.evaluate_tenant(&ds, keep)?;
    let snap = server.evict(keep)?;
    let back = server.restore(snap)?;
    let acc_after = server.evaluate_tenant(&ds, back)?;
    ensure!(
        acc_before == acc_after,
        "evict/restore changed tenant accuracy: {acc_before} != {acc_after}"
    );
    println!("evict/restore round-trip: tenant {keep} -> {back}, accuracy preserved");

    // ---- 5. the tiered replay hierarchy: same RAM budget, 2x tenants ----
    // nominal capacity = how many Q8 tenants the flat (no-spill) budget
    // holds; the cold tier must host twice that under the SAME budget,
    // spilling the coldest to disk and restoring them lazily on traffic
    let per_tenant = server.per_tenant_bytes(p.n_lr, 8);
    let nominal = (p.budget_bytes - server.shared_backbone_bytes()) / per_tenant;
    let n_tiered = nominal * 2;
    ensure!(nominal >= 2, "profile too small for the tiered capacity demo");
    println!(
        "\n== tiered replay hierarchy: {n_tiered} tenants (2x the nominal {nominal}) \
         under the same {} MB budget ==",
        p.budget_bytes / (1024 * 1024)
    );
    let spill_dir = std::env::temp_dir().join(format!("tinycl_spill_{}", std::process::id()));
    // start from an empty cold tier: the server's crash-recovery scan
    // would (correctly) re-register any snapshots a crashed earlier run
    // left behind, which is not the story this act measures
    std::fs::remove_dir_all(&spill_dir).ok();
    let tiered_cfg = FleetConfig::builder(SPLIT)
        .budget_bytes(p.budget_bytes)
        .max_tenants(n_tiered.max(64))
        .spill_dir(spill_dir.clone())
        .build()?;
    let low_bytes = (tiered_cfg.governor.low_watermark * p.budget_bytes as f64) as usize;
    let tiered = FleetServer::new(be.clone(), tiered_cfg)?;
    let tiered_init = tiered.embed_images(&init_images)?;
    let mut tids = Vec::with_capacity(n_tiered);
    for t in 0..n_tiered {
        let tc = TenantConfig { n_lr: p.n_lr, seed: 100 + t as u64, ..TenantConfig::default() };
        tids.push(tiered.admit_prepared(tc, &tiered_init, &init_labels)?);
    }
    // admission outcome is single-threaded and therefore deterministic
    let admit_tally = tiered.governor_tally();
    println!(
        "admitted {}: {} resident / {} cold ({} spills, {} demotions; \
         {:.1} MB RAM + {:.1} MB disk)",
        tids.len(),
        tiered.tenant_count(),
        tiered.spilled_count(),
        admit_tally.spills,
        admit_tally.demotes,
        tiered.bytes_in_use() as f64 / (1024.0 * 1024.0),
        tiered.spilled_disk_bytes() as f64 / (1024.0 * 1024.0)
    );
    ensure!(admit_tally.admits == n_tiered, "tiered fleet admission was rejected");
    ensure!(admit_tally.rejects == 0, "tiered fleet saw rejections");
    ensure!(admit_tally.spills >= 1, "expected at least one spill to the cold tier");
    ensure!(admit_tally.demotes >= 1, "expected 8->7-bit demotions before the spills");
    ensure!(
        tiered.bytes_in_use() <= p.budget_bytes,
        "tiered budget violated: {} > {}",
        tiered.bytes_in_use(),
        p.budget_bytes
    );

    // the full per-tenant event schedule: events for cold tenants
    // transparently restore them (spilling colder peers — the lossless
    // in-run relief mode, so outcomes stay worker-count independent)
    let tiered_seeded: Vec<(usize, u64)> = tids.iter().map(|&id| (id, 100 + id as u64)).collect();
    let tiered_events = traffic::interleaved_nicv2(
        &be.manifest().protocol,
        &ds,
        &tiered_seeded,
        p.events_per_tenant,
    );
    let n_tiered_events = tiered_events.len();
    let tiered_report = tiered.run(tiered_events, workers)?;
    ensure!(tiered_report.dropped == 0, "tiered serving dropped events");
    ensure!(
        tiered_report.events as usize == n_tiered_events,
        "not all tiered events were applied"
    );
    ensure!(
        tiered_report.lazy_restores >= 1,
        "expected at least one lazy restore from the cold tier"
    );
    println!(
        "served {} events at {:.1} events/s with {} lazy restores from disk",
        tiered_report.events, tiered_report.events_per_sec, tiered_report.lazy_restores
    );
    let trb = &tiered_report.robustness;
    println!(
        "tiered robustness: {} shed, {} I/O retries, {} degrades",
        trb.shed, trb.io_retries, trb.degrades
    );

    // per-tenant accuracy over ALL 2x tenants — deterministic for any
    // worker count because in-run governor activity is spill-only
    // (lossless); evaluation readmits cold tenants as needed
    let mut tiered_accs = Vec::with_capacity(tids.len());
    for &id in &tids {
        tiered_accs.push(tiered.evaluate_tenant(&ds, id)?);
    }
    let tiered_mean = tiered_accs.iter().sum::<f64>() / tiered_accs.len() as f64;
    println!("tiered tenant accuracy: mean {tiered_mean:.3} over {} tenants", tids.len());
    // smoke floor only: ONE event per tenant at the pooled split is the
    // weakest learning regime in the repo (and the round-to-nearest
    // weight grid feeds the head larger, more faithful latents than the
    // old floor-biased one) — above-chance is the right bar here; the
    // governed act above asserts the stronger mean
    ensure!(tiered_mean > 0.10, "tiered fleet failed to learn ({tiered_mean:.3})");

    // promotion: drop the load below the low watermark (evict most
    // residents, keeping one demoted — hence 7-bit — tenant), then let
    // rebalance() walk the ladder back up: 7→8-bit re-widen first, cold
    // readmissions after, all capped at the high watermark
    let is_warm = |id: usize| -> Result<bool> {
        let m = tiered.tenant_metrics(id)?;
        Ok(m.demotions > 0 && m.promotions == 0)
    };
    let mut warm_keep = None;
    for id in tiered.resident_ids() {
        if is_warm(id)? {
            warm_keep = Some(id);
            break;
        }
    }
    if warm_keep.is_none() {
        // every demoted tenant happens to be cold: pull one back in
        for id in tiered.spilled_ids() {
            if is_warm(id)? {
                let snap = tiered.evict(id)?; // straight off the disk
                warm_keep = Some(tiered.restore(snap)?);
                break;
            }
        }
    }
    let warm_keep = warm_keep.expect("demotions happened, so a 7-bit tenant exists somewhere");
    for id in tiered.resident_ids() {
        if id != warm_keep && tiered.bytes_in_use() >= low_bytes {
            tiered.evict(id)?;
        }
    }
    ensure!(
        tiered.bytes_in_use() < low_bytes,
        "could not quiesce below the low watermark"
    );
    let boost = tiered.rebalance()?;
    println!(
        "rebalance after load drop: {} promoted 7->8-bit, {} readmitted from disk \
         ({} resident / {} cold, {:.1} MB in use)",
        boost.promoted,
        boost.unspilled,
        tiered.tenant_count(),
        tiered.spilled_count(),
        tiered.bytes_in_use() as f64 / (1024.0 * 1024.0)
    );
    ensure!(boost.promoted >= 1, "expected at least one 7->8-bit promotion");
    let keep_metrics = tiered.tenant_metrics(warm_keep)?;
    ensure!(keep_metrics.promotions >= 1, "the kept 7-bit tenant was not promoted");
    ensure!(
        tiered.bytes_in_use() <= p.budget_bytes,
        "rebalance overshot the budget"
    );

    // ---- BENCH_fleet.json ----------------------------------------------
    let mut grid_json = Vec::new();
    for (n, r) in &grid_rows {
        let mut o = BTreeMap::new();
        o.insert("tenants".into(), Json::Num(*n as f64));
        o.insert("events".into(), Json::Num(r.events as f64));
        o.insert("events_per_sec".into(), Json::Num(round3(r.events_per_sec)));
        o.insert("p50_ms".into(), Json::Num(round3(r.latency.p50_ms)));
        o.insert("p99_ms".into(), Json::Num(round3(r.latency.p99_ms)));
        o.insert("mean_events_per_frozen_call".into(), Json::Num(round3(r.mean_coalesce)));
        grid_json.push(Json::Obj(o));
    }
    let mut root = BTreeMap::new();
    root.insert(
        "description".into(),
        Json::Str(
            "Fleet serving throughput/latency: N concurrent QLR-CL tenants on one shared \
             frozen backbone (rust/src/fleet/), events/sec and per-event latency vs tenant \
             count, plus the governor outcome of the pressured max-tenant run."
                .into(),
        ),
    );
    root.insert(
        "methodology".into(),
        Json::Str(format!(
            "cargo run --release --example fleet_serving {} {workers} — {} events per \
             tenant of the NICv2-mini synthetic protocol at split l={SPLIT}, N_LR={}, \
             UINT-8 replays, {workers} workers, coalesce 8; regenerate on any host with \
             a rust toolchain",
            p.name, p.events_per_tenant, p.n_lr
        )),
    );
    root.insert("profile".into(), Json::Str(p.name.into()));
    root.insert("grid".into(), Json::Arr(grid_json));
    let mut gov = BTreeMap::new();
    gov.insert("budget_mb".into(), Json::Num((p.budget_bytes / (1024 * 1024)) as f64));
    gov.insert("tenants_admitted".into(), Json::Num(admits as f64));
    gov.insert("demotions_8_to_7".into(), Json::Num(demotes as f64));
    gov.insert("shrinks".into(), Json::Num(shrinks as f64));
    gov.insert(
        "bytes_in_use_mb".into(),
        Json::Num(round3(server.bytes_in_use() as f64 / (1024.0 * 1024.0))),
    );
    gov.insert("mean_tenant_accuracy".into(), Json::Num(round3(mean_acc)));
    gov.insert("n1_parity_accuracy".into(), Json::Num(fleet_acc));
    root.insert("governed_max_run".into(), Json::Obj(gov));
    let final_tally = tiered.governor_tally();
    let mut tier = BTreeMap::new();
    tier.insert("budget_mb".into(), Json::Num((p.budget_bytes / (1024 * 1024)) as f64));
    tier.insert("nominal_capacity".into(), Json::Num(nominal as f64));
    tier.insert("tenants_admitted".into(), Json::Num(n_tiered as f64));
    tier.insert("capacity_x".into(), Json::Num(round3(n_tiered as f64 / nominal as f64)));
    tier.insert("admission_spills".into(), Json::Num(admit_tally.spills as f64));
    tier.insert("admission_demotions".into(), Json::Num(admit_tally.demotes as f64));
    tier.insert("lazy_restores".into(), Json::Num(tiered_report.lazy_restores as f64));
    tier.insert(
        "serve_events_per_sec".into(),
        Json::Num(round3(tiered_report.events_per_sec)),
    );
    tier.insert("mean_tenant_accuracy".into(), Json::Num(round3(tiered_mean)));
    tier.insert("rebalance_promoted".into(), Json::Num(boost.promoted as f64));
    tier.insert("rebalance_unspilled".into(), Json::Num(boost.unspilled as f64));
    tier.insert("total_spills".into(), Json::Num(final_tally.spills as f64));
    tier.insert("total_unspills".into(), Json::Num(final_tally.unspills as f64));
    root.insert("tiered_run".into(), Json::Obj(tier));
    // telemetry digest of the governed max run: exact log2-histogram
    // percentiles of the dispatch/serve paths plus the SLO counters
    // (`bench_check.py validate-telemetry` floors dispatch p99). Like
    // the grid's p50/p99, timing-dependent — NOT in the determinism
    // subset below.
    if let Some(td) = &main_tm {
        let mut tj = BTreeMap::new();
        tj.insert("events_recorded".into(), Json::Num(td.events_recorded as f64));
        tj.insert("events_dropped".into(), Json::Num(td.events_dropped as f64));
        tj.insert("threads_traced".into(), Json::Num(td.threads_traced as f64));
        for path in ["dispatch", "serve", "eval"] {
            if let Some(h) = td.hist(path) {
                tj.insert(path.into(), h.to_json());
            }
        }
        let mut cj = BTreeMap::new();
        for (name, v) in &td.counters {
            cj.insert((*name).into(), Json::Num(*v as f64));
        }
        tj.insert("counters".into(), Json::Obj(cj));
        let r = grid_rows.last().map(|(_, r)| r.robustness).unwrap_or_default();
        let mut rj = BTreeMap::new();
        rj.insert("shed".into(), Json::Num(r.shed as f64));
        rj.insert("io_retries".into(), Json::Num(r.io_retries as f64));
        rj.insert("degrades".into(), Json::Num(r.degrades as f64));
        tj.insert("robustness".into(), Json::Obj(rj));
        root.insert("telemetry".into(), Json::Obj(tj));
    }
    // the subset the CI determinism job diffs across two same-seed runs:
    // everything here is independent of worker scheduling (admissions
    // are single-threaded; in-run relief is lossless spill-only; event
    // counts and accuracies are pinned by the per-tenant seeds)
    let mut det = BTreeMap::new();
    det.insert("n1_parity_accuracy".into(), Json::Num(fleet_acc));
    det.insert("governed_admits".into(), Json::Num(admits as f64));
    det.insert("governed_demotions".into(), Json::Num(demotes as f64));
    det.insert("governed_mean_accuracy".into(), Json::Num(mean_acc));
    det.insert(
        "grid_events".into(),
        Json::Arr(grid_rows.iter().map(|(_, r)| Json::Num(r.events as f64)).collect()),
    );
    det.insert("tiered_nominal".into(), Json::Num(nominal as f64));
    det.insert("tiered_admitted".into(), Json::Num(n_tiered as f64));
    det.insert("tiered_admission_spills".into(), Json::Num(admit_tally.spills as f64));
    det.insert("tiered_admission_demotions".into(), Json::Num(admit_tally.demotes as f64));
    det.insert("tiered_events".into(), Json::Num(tiered_report.events as f64));
    det.insert("tiered_mean_accuracy".into(), Json::Num(tiered_mean));
    root.insert("determinism".into(), Json::Obj(det));
    std::fs::write("BENCH_fleet.json", Json::Obj(root).to_string() + "\n")?;
    // Chrome trace of the recorded run (chrome://tracing / Perfetto);
    // `bench_check.py validate-telemetry` checks span balance and
    // per-thread timestamp monotonicity on this artifact
    if let Some(trace) = server.config().telemetry.chrome_trace() {
        std::fs::write("BENCH_fleet.trace.json", trace.to_string() + "\n")?;
        println!("wrote BENCH_fleet.trace.json");
    }
    std::fs::remove_dir_all(&spill_dir).ok();
    println!("\nwrote BENCH_fleet.json");
    println!("fleet_serving OK");
    Ok(())
}

fn round3(v: f64) -> f64 {
    (v * 1000.0).round() / 1000.0
}
