//! Quickstart: open the default backend, run ONE learning event
//! end-to-end, and print what happened. This is the smallest useful tour
//! of the public API.
//!
//!     cargo run --release --example quickstart
//!
//! Pipeline exercised: frozen INT-8 forward -> quantized replay buffer ->
//! mini-batch mixing -> adaptive-stage training -> test-set evaluation.
//! Uses PJRT over AOT HLO modules when `artifacts/` exists (`make
//! artifacts`), otherwise the native kernel engine on the synthetic
//! Core50-mini — either way, no setup needed.

use anyhow::Result;
use tinycl::coordinator::{CLConfig, Session};
use tinycl::runtime::open_default_backend;

fn main() -> Result<()> {
    let (be, ds) = open_default_backend()?;
    let m = be.manifest();
    println!("platform      : {}", be.platform());
    println!("model         : MicroNet-32, {} params, {} classes", m.num_params, m.num_classes);
    println!("splits        : {:?}", m.splits);
    println!("batch         : {} train ({} new + {} replay), {} eval",
        m.batch_train, m.batch_new, m.batch_train - m.batch_new, m.batch_eval);

    println!("dataset       : {} train / {} test images ({}x{})",
        ds.n_train(), ds.n_test(), ds.input_hw, ds.input_hw);

    // A cluster-B style configuration: INT-8 frozen stage, 8-bit LRs.
    let cfg = CLConfig { l: 13, n_lr: 256, lr_bits: 8, int8_frozen: true, ..Default::default() };
    println!("config        : {}", cfg.label());

    let mut session = Session::new(&*be, &ds, cfg)?;
    println!("replay memory : {} latents x {} elems = {} bytes ({}x smaller than FP32)",
        cfg.n_lr, session.latent_elems(),
        session.replay.storage_bytes(),
        (cfg.n_lr * session.latent_elems() * 4) / session.replay.storage_bytes());

    let acc0 = session.evaluate(&ds)?;
    println!("accuracy      : {:.3} before any on-device learning", acc0);

    // Learn one event: a brand-new class (class 5, session 0).
    let t = std::time::Instant::now();
    let stats = session.run_event(&ds, 5, 0)?;
    let acc1 = session.evaluate(&ds)?;
    println!(
        "event         : class 5 learned in {:?} ({} SGD steps, mean loss {:.3})",
        t.elapsed(), stats.steps, stats.mean_loss
    );
    println!("accuracy      : {:.3} -> {:.3} (one event)", acc0, acc1);
    println!("replay update : {} slots replaced", stats.replaced);
    println!("histogram     : {:?}", session.replay.class_histogram(m.num_classes));
    println!("\nquickstart OK");
    Ok(())
}
