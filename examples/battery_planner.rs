//! Battery planner (the deployment question behind Fig. 10): given an
//! adaptation requirement — how often the node must learn, and from which
//! layer — report per-event latency/energy and the achievable battery
//! life on VEGA vs an STM32L4, flagging infeasible duty cycles.
//!
//!     cargo run --release --example battery_planner [--rate 60] [--mah 3300]

use anyhow::Result;
use tinycl::models::mobilenet_v1_128;
use tinycl::simulator::energy;
use tinycl::simulator::executor::{event_seconds, EventSpec};
use tinycl::simulator::targets::{stm32l4, vega};
use tinycl::util::cli;
use tinycl::util::table::{fmt, fmt_eng, Table};

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = cli::parse(&raw, &[]);
    let rate = args.f64_or("rate", 60.0); // events per hour
    let mah = args.f64_or("mah", energy::BATTERY_MAH);

    let net = mobilenet_v1_128();
    let ev = EventSpec::paper();
    let capacity_scale = mah / energy::BATTERY_MAH;

    println!(
        "battery plan: {rate} learning events/hour, {mah} mAh battery\n\
         (event = 21 new images, 40 mini-batches of 128 latents — §V-E)\n"
    );

    let mut t = Table::new(
        "deployment options",
        &[
            "target",
            "LR layer",
            "event [s]",
            "event [J]",
            "duty cycle",
            "lifetime [h]",
            "lifetime [days]",
        ],
    );
    for target in [vega(), stm32l4()] {
        for l in [27usize, 26, 25, 24, 23, 22, 21, 20] {
            let secs = event_seconds(&target, &target.default_hw, &net, l, &ev);
            let joules = target.energy_j(secs);
            let duty = secs * rate / 3600.0;
            let life = energy::lifetime_hours(&target, &target.default_hw, &net, l, &ev, rate)
                .map(|h| h * capacity_scale);
            t.row(vec![
                target.name.into(),
                l.to_string(),
                fmt_eng(secs),
                fmt_eng(joules),
                if duty > 1.0 { "INFEASIBLE".into() } else { format!("{:.1}%", duty * 100.0) },
                life.map(fmt_eng).unwrap_or_else(|| "-".into()),
                life.map(|h| fmt(h / 24.0, 1)).unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    t.print();
    t.save_tsv("results", "battery_plan")?;

    // headline scenario from the abstract: one mini-batch per minute,
    // last layer only
    let v = vega();
    let mini = EventSpec { batch: 128, iters: 1, new_images: 21 };
    let life = energy::lifetime_hours(&v, &v.default_hw, &net, 27, &mini, 60.0).unwrap();
    println!(
        "\nabstract scenario (one mini-batch/minute, last layer): {:.0} h (~{:.0} days) on VEGA",
        life * capacity_scale,
        life * capacity_scale / 24.0
    );
    Ok(())
}
